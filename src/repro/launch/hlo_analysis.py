"""Post-SPMD HLO cost extraction for the roofline analysis.

``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified in this
container), which would undercount every layer-scan by ~L x.  This module
re-derives the three roofline inputs from ``compiled.as_text()``:

  * FLOPs        — every ``dot`` op: 2 * prod(result) * contracted size,
                   scaled by the product of enclosing loop trip counts
                   (read from XLA's ``known_trip_count`` backend config);
  * HBM bytes    — operand+result bytes of every op at fusion boundaries
                   (insides of fusions stay in registers/VMEM), same scaling;
  * collective bytes — per-device wire traffic of all-gather / all-reduce /
                   reduce-scatter / all-to-all / collective-permute with the
                   standard ring formulas over the participant group size.

All quantities are PER DEVICE (the HLO is the per-device SPMD program).
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+"
                     r"([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->")
_PARAM_RE = re.compile(r"([\w.\-]+):\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\]))")
_TRIP_RE = re.compile(r'known_trip_count"?\s*:\s*\{"?n"?\s*:\s*"?(\d+)')
_GROUPS_NEW_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_OLD_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

# boundary opcodes whose operands/results count as HBM traffic
_SKIP_BYTES_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
                   "bitcast", "bitcast-convert", "after-all", "partition-id",
                   "replica-id", "iota"}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class OpInfo:
    name: str
    opcode: str
    result_type: str
    operands: List[str]
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    param_types: Dict[str, str]
    ops: List[OpInfo]
    is_fusion: bool


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    current: Optional[Computation] = None
    for rawline in text.splitlines():
        line = rawline.strip()
        if not line or line.startswith("//") or line.startswith("HloModule"):
            continue
        if line.endswith("{") and ("->" in line):
            m = _COMP_HDR_RE.match(line)
            if m:
                name, params = m.group(1), m.group(2)
                ptypes = {pm.group(1): pm.group(2)
                          for pm in _PARAM_RE.finditer(params)}
                current = Computation(
                    name=name, param_types=ptypes, ops=[],
                    is_fusion=name.startswith("fused_") or ".fused" in name
                    or name.startswith("wrapped_"))
                comps[name] = current
            continue
        if line == "}" or line.startswith("}"):
            continue
        if current is None:
            continue
        m = _DEF_RE.match(line)
        if m:
            name, rtype, opcode, rest = m.groups()
            operands = re.findall(r"%([\w.\-]+)", rest.split(", metadata")[0])
            current.ops.append(OpInfo(name=name, opcode=opcode,
                                      result_type=rtype.strip(),
                                      operands=operands, line=line))
    return comps


def _symbol_table(comp: Computation) -> Dict[str, str]:
    table = dict(comp.param_types)
    for op in comp.ops:
        table[op.name] = op.result_type
    return table


def _multipliers(comps: Dict[str, Computation]) -> Dict[str, float]:
    """Propagate loop trip counts through the call graph from ENTRY."""
    entry = None
    for name, c in comps.items():
        if name.startswith("main") or ".main" in name or name.endswith("_spmd") \
                and entry is None:
            pass
    # ENTRY is whichever computation is not referenced by any other
    referenced = set()
    calls: Dict[str, List[tuple]] = defaultdict(list)  # parent -> (child, mult)
    for name, c in comps.items():
        for op in c.ops:
            line = op.line
            for kw in ("body=", "condition=", "calls=", "to_apply=",
                       "branch_computations={", "true_computation=",
                       "false_computation="):
                for m in re.finditer(re.escape(kw) + r"[{]?%([\w.\-]+)", line):
                    child = m.group(1)
                    referenced.add(child)
                    mult = 1.0
                    if kw in ("body=", "condition="):
                        tm = _TRIP_RE.search(line)
                        mult = float(tm.group(1)) if tm else 1.0
                    calls[name].append((child, mult))
    roots = [n for n in comps if n not in referenced]
    mults = {n: 0.0 for n in comps}

    def visit(name, m):
        if name not in comps:
            return
        mults[name] += m
        for child, cm in calls.get(name, []):
            visit(child, m * cm)

    for r in roots:
        visit(r, 1.0)
    return mults


def _dot_flops(op: OpInfo, table: Dict[str, str]) -> float:
    result_dims = _shape_dims(op.result_type)
    if result_dims is None:
        return 0.0
    out = math.prod(result_dims) if result_dims else 1
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
    lhs_type = table.get(op.operands[0]) if op.operands else None
    if not m or lhs_type is None:
        return 2.0 * out  # degenerate
    lhs_dims = _shape_dims(lhs_type) or []
    contract = 1
    for idx in (int(i) for i in m.group(1).split(",") if i):
        if idx < len(lhs_dims):
            contract *= lhs_dims[idx]
    return 2.0 * out * contract


def _collective_bytes(op: OpInfo, table: Dict[str, str]) -> float:
    """Per-device wire bytes with ring formulas."""
    gsz = None
    m = _GROUPS_NEW_RE.search(op.line)
    if m:
        gsz = int(m.group(2))
    else:
        m = _GROUPS_OLD_RE.search(op.line)
        if m:
            gsz = len(m.group(1).split(","))
    if not gsz or gsz <= 1:
        gsz = 2  # conservative
    frac = (gsz - 1) / gsz
    out_b = _shape_bytes(op.result_type)
    in_b = sum(_shape_bytes(table.get(o, "")) for o in op.operands)
    if op.opcode == "all-gather":
        return out_b * frac
    if op.opcode == "all-reduce":
        return 2.0 * out_b * frac
    if op.opcode == "reduce-scatter":
        return in_b * frac
    if op.opcode == "all-to-all":
        return out_b * frac
    if op.opcode == "collective-permute":
        return out_b
    return 0.0


@dataclasses.dataclass
class HloCosts:
    flops: float               # per device
    bytes_accessed: float      # per device, fusion-boundary traffic
    collective_bytes: float    # per device wire bytes
    collective_breakdown: Dict[str, float]
    n_collectives: int


def analyze(text: str) -> HloCosts:
    comps = parse_hlo(text)
    mults = _multipliers(comps)
    flops = 0.0
    bytes_acc = 0.0
    coll = 0.0
    breakdown: Dict[str, float] = defaultdict(float)
    ncoll = 0
    for name, comp in comps.items():
        mult = mults.get(name, 1.0)
        if mult == 0.0:
            mult = 1.0  # unreachable safety
        table = _symbol_table(comp)
        for op in comp.ops:
            if op.opcode in ("dot", "convolution"):
                flops += mult * _dot_flops(op, table)
            if op.opcode in COLLECTIVE_OPS:
                b = mult * _collective_bytes(op, table)
                coll += b
                breakdown[op.opcode] += b
                ncoll += 1
            if not comp.is_fusion and op.opcode not in _SKIP_BYTES_OPS:
                out_b = _shape_bytes(op.result_type)
                in_b = sum(_shape_bytes(table.get(o, "")) for o in op.operands)
                bytes_acc += mult * (out_b + in_b)
    return HloCosts(flops=flops, bytes_accessed=bytes_acc,
                    collective_bytes=coll,
                    collective_breakdown=dict(breakdown), n_collectives=ncoll)
