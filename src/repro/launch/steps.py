"""Step builders: jit-able, sharded train/prefill/serve steps per
(architecture x input shape x mesh x sharding policy).

These are exactly what the multi-pod dry-run lowers and what train.py /
serve.py execute. The LoRA adapters + optimizer state are ARGUMENTS of the
compiled executable (never baked in), so the server's per-client adapter
switching is a buffer swap — the paper's memory-efficiency mechanism in
XLA-native form (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape
from repro.launch.mesh import dp_axes
from repro.launch.sharding import (ShardingPolicy, batch_shardings,
                                   hidden_constraint, lora_shardings,
                                   param_shardings)
from repro.models import build_model, input_specs, long_context_variant
from repro.optim import AdamW, AdamWState

PyTree = Any


@dataclasses.dataclass
class StepBundle:
    name: str
    cfg: ModelConfig
    fn: Callable                    # jitted step
    args: Tuple[PyTree, ...]        # ShapeDtypeStruct stand-ins for .lower()
    mesh: Mesh

    def lower(self):
        with self.mesh:
            return self.fn.lower(*self.args)


def _dp_total(mesh: Mesh) -> int:
    return math.prod(mesh.shape[a] for a in dp_axes(mesh))


def _total_seq(cfg: ModelConfig, shape: InputShape) -> int:
    if cfg.family == "vlm":
        return shape.seq_len  # vision prefix + text = assigned seq_len
    return shape.seq_len


def resolve_cfg(cfg: ModelConfig, shape: InputShape,
                swa_window: int = 8192) -> ModelConfig:
    """Apply the long-context sliding-window variant where required."""
    if shape.name == "long_500k" and cfg.family not in ("ssm",):
        return long_context_variant(cfg, swa_window)
    return cfg


def build_step(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
               policy: ShardingPolicy = ShardingPolicy(), *,
               lr: float = 1e-5, remat: bool = True,
               donate: bool = False) -> StepBundle:
    cfg = resolve_cfg(cfg, shape)
    model = build_model(cfg)
    opt = AdamW(lr)
    dp_tot = _dp_total(mesh)
    constrain = hidden_constraint(mesh, policy)

    pspec = model.params_spec()
    lspec = model.lora_spec()
    p_sh = param_shardings(cfg, pspec, mesh, policy)
    l_sh = lora_shardings(lspec, mesh, policy)

    cache_len = None
    if shape.kind == "decode":
        cache_len = cfg.sliding_window if cfg.sliding_window else shape.seq_len
    specs = input_specs(cfg, shape, model, cache_len=cache_len)
    b_sh = batch_shardings(specs, mesh)

    if shape.kind == "train":
        ospec = jax.eval_shape(opt.init, lspec)
        o_sh = AdamWState(step=NamedSharding(mesh, P()),
                          mu=lora_shardings(ospec.mu, mesh, policy),
                          nu=lora_shardings(ospec.nu, mesh, policy))

        def batch_loss(params, lo, batch):
            if cfg.family == "encdec":
                loss, _ = model.loss(params, lo, batch, remat=remat)
                return loss
            seq_tot = _total_seq(cfg, shape)
            ctx = model.make_ctx(seq_tot, moe_groups=dp_tot,
                                 constrain=constrain,
                                 moe_mesh=mesh if policy.moe_shard_map else None,
                                 moe_dp_axes=dp_axes(mesh))
            loss, _ = model.loss(params, lo, batch, cut=0, side="full",
                                 path="scan", remat=remat, ctx=ctx)
            return loss

        mb = max(policy.microbatch, 1)
        if mb > 1 and all(v.shape[0] % mb == 0 for v in jax.tree.leaves(specs)):
            # gradient accumulation: scan over microbatches — activation
            # peak scales with B/mb; one optimizer update per global batch
            def step(params, lora, opt_state, batch):
                micro = jax.tree.map(
                    lambda v: v.reshape((mb, v.shape[0] // mb) + v.shape[1:]),
                    batch)

                def acc_fn(carry, mbatch):
                    loss_sum, g_sum = carry
                    loss, g = jax.value_and_grad(
                        lambda lo: batch_loss(params, lo, mbatch))(lora)
                    return (loss_sum + loss,
                            jax.tree.map(jnp.add, g_sum, g)), None

                g0 = jax.tree.map(lambda l: jnp.zeros(l.shape, jnp.float32), lora)
                (loss_sum, g), _ = jax.lax.scan(acc_fn, (jnp.float32(0.0), g0),
                                                micro)
                g = jax.tree.map(lambda x: x / mb, g)
                new_lora, new_opt = opt.update(g, opt_state, lora)
                return loss_sum / mb, new_lora, new_opt
        else:
            def step(params, lora, opt_state, batch):
                loss, g = jax.value_and_grad(
                    lambda lo: batch_loss(params, lo, batch))(lora)
                new_lora, new_opt = opt.update(g, opt_state, lora)
                return loss, new_lora, new_opt

        fn = jax.jit(step, in_shardings=(p_sh, l_sh, o_sh, b_sh),
                     donate_argnums=(1, 2) if donate else ())
        args = (pspec, lspec, ospec, specs)
        return StepBundle(shape.step_name, cfg, fn, args, mesh)

    if shape.kind == "prefill":
        if cfg.family == "encdec":
            def step(params, lora, batch):
                return model.prefill(params, lora, batch)
        else:
            seq_tot = _total_seq(cfg, shape)

            def step(params, lora, batch):
                ctx = model.make_ctx(seq_tot, moe_groups=dp_tot,
                                     constrain=constrain,
                                     moe_mesh=mesh if policy.moe_shard_map else None,
                                     moe_dp_axes=dp_axes(mesh))
                return model.prefill(params, lora, batch, ctx=ctx)

        fn = jax.jit(step, in_shardings=(p_sh, l_sh, b_sh))
        args = (pspec, lspec, specs)
        return StepBundle(shape.step_name, cfg, fn, args, mesh)

    if shape.kind == "decode":
        c_sh = b_sh["cache"]
        t_sh = b_sh["token"]
        pos_sh = b_sh["pos"]
        window = cfg.sliding_window

        def step(params, lora, cache, token, pos):
            return model.serve_step(params, lora, cache, token, pos,
                                    window=window)

        fn = jax.jit(step, in_shardings=(p_sh, l_sh, c_sh, t_sh, pos_sh),
                     donate_argnums=(2,) if donate else ())
        args = (pspec, lspec, specs["cache"], specs["token"], specs["pos"])
        return StepBundle(shape.step_name, cfg, fn, args, mesh)

    raise ValueError(shape.kind)


def build_server_resume_step(cfg: ModelConfig, mesh: Mesh,
                             policy: ShardingPolicy = ShardingPolicy(), *,
                             batch: int, seq_len: int, lr: float = 1e-5,
                             remat: bool = True) -> StepBundle:
    """The paper's Alg.1 server step (Eq. 4) as a production executable:
    resume at a TRACED cut from uploaded activations; one compiled program
    serves every client/cut."""
    model = build_model(cfg)
    opt = AdamW(lr)
    dp_tot = _dp_total(mesh)
    constrain = hidden_constraint(mesh, policy)

    pspec = model.params_spec()
    lspec = model.lora_spec()
    p_sh = param_shardings(cfg, pspec, mesh, policy)
    l_sh = lora_shardings(lspec, mesh, policy)
    ospec = jax.eval_shape(opt.init, lspec)
    o_sh = AdamWState(step=NamedSharding(mesh, P()),
                      mu=lora_shardings(ospec.mu, mesh, policy),
                      nu=lora_shardings(ospec.nu, mesh, policy))

    sds = jax.ShapeDtypeStruct
    act = jnp.dtype(cfg.dtype)
    v_spec = sds((batch, seq_len, cfg.d_model), act)
    if cfg.n_classes:
        bspec = {"tokens": sds((batch, seq_len), jnp.int32),
                 "label": sds((batch,), jnp.int32)}
    else:
        bspec = {"tokens": sds((batch, seq_len), jnp.int32),
                 "targets": sds((batch, seq_len), jnp.int32)}
    cut_spec = sds((), jnp.int32)
    dp = dp_axes(mesh)
    v_sh = NamedSharding(mesh, P(dp if batch % dp_tot == 0 else None, None, None))
    b_sh = batch_shardings(bspec, mesh)

    def step(params, lora, opt_state, v, batch_d, cut):
        ctx = model.make_ctx(seq_len, moe_groups=dp_tot, constrain=constrain)

        def loss_fn(lo, vv):
            loss, _ = model.loss(params, lo, batch_d, cut=cut, side="server",
                                 path="scan", remat=remat, ctx=ctx, x0=vv)
            return loss

        loss, (g_lora, g_v) = jax.value_and_grad(loss_fn, argnums=(0, 1))(lora, v)
        new_lora, new_opt = opt.update(g_lora, opt_state, lora)
        return loss, new_lora, new_opt, g_v

    fn = jax.jit(step, in_shardings=(p_sh, l_sh, o_sh, v_sh, b_sh,
                                     NamedSharding(mesh, P())))
    args = (pspec, lspec, ospec, v_spec, bspec, cut_spec)
    return StepBundle("server_resume_step", cfg, fn, args, mesh)
