"""GSPMD sharding policy: megatron-style tensor parallelism over "model",
batch over ("pod","data"), optional FSDP weight sharding and sequence
sharding (the §Perf knobs).

All rules are path-pattern driven over the parameter pytrees produced by
``repro.models``; dimensions index from the END of each leaf shape so the
same rule covers stacked (L, ...) and unstacked leaves.
"""
from __future__ import annotations

import dataclasses
import fnmatch
from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch.mesh import dp_axes

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    fsdp: bool = False            # additionally shard weights over "data"
    seq_shard: bool = False       # shard the seq dim of hidden states over "model"
    shard_vocab_embed: bool = True
    shard_lora: bool = False      # adapters are tiny; replicate by default
    moe_shard_map: bool = False   # shard_map MoE: local dispatch + combine-then-reduce
    microbatch: int = 1           # gradient-accumulation steps (peak-memory /k)


# (pattern, kind) — kind: "col" (shard last dim), "row" (shard dim -2),
# "vocab" (embedding), "rep" (replicate). First match wins.
_RULES = [
    ("*/cm/wk", "col"), ("*/cm/wv", "row"), ("*/cm/wr", "col"),
    ("*/tm/wr", "col"), ("*/tm/wk", "col"), ("*/tm/wv", "col"),
    ("*/tm/wg", "col"), ("*/tm/wo", "row"), ("*/tm/*", "rep"),
    ("*/cm/*", "rep"),
    ("*wr_router", "rep"),
    ("*/experts/we_u", "col"), ("*/experts/we_g", "col"),
    ("*/experts/we_d", "row"),
    ("*/attn/wq", "col"), ("*/attn/wk", "col"), ("*/attn/wv", "col"),
    ("*/attn/bq", "col"), ("*/attn/bk", "col"), ("*/attn/bv", "col"),
    ("*/attn/wo", "row"),
    ("*/xattn/wq", "col"), ("*/xattn/wk", "col"), ("*/xattn/wv", "col"),
    ("*/xattn/bq", "col"), ("*/xattn/bk", "col"), ("*/xattn/bv", "col"),
    ("*/xattn/wo", "row"),
    ("*/mlp/wu", "col"), ("*/mlp/wg", "col"), ("*/mlp/wd", "row"),
    ("*in_proj", "col"), ("*out_proj", "row"),
    ("embed", "vocab"), ("head", "col"), ("cls_head", "rep"),
    ("pos_embed", "rep"), ("enc_pos", "rep"), ("proj", "rep"),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _spec_for(kind: str, ndim: int, policy: ShardingPolicy,
              divisible_last: bool, divisible_row: bool) -> P:
    none = [None] * ndim
    if kind == "rep" or ndim < 2:
        return P(*none)
    fs = "data" if policy.fsdp else None
    if kind == "col":
        spec = list(none)
        if divisible_last:
            spec[-1] = "model"
            spec[-2] = fs
        return P(*spec)
    if kind == "row":
        spec = list(none)
        if divisible_row:
            spec[-2] = "model"
            spec[-1] = fs
        return P(*spec)
    if kind == "vocab":
        spec = list(none)
        spec[0] = "model" if policy.shard_vocab_embed else None
        spec[1] = fs
        return P(*spec)
    raise ValueError(kind)


def param_shardings(cfg: ModelConfig, params_spec: PyTree, mesh: Mesh,
                    policy: ShardingPolicy = ShardingPolicy()) -> PyTree:
    nmodel = mesh.shape.get("model", 1)
    ndata = mesh.shape.get("data", 1)

    def assign(path, leaf):
        ps = _path_str(path)
        shape = leaf.shape
        kind = "rep"
        for pattern, k in _RULES:
            if fnmatch.fnmatch(ps, pattern) or ps == pattern.lstrip("*/"):
                kind = k
                break
        if leaf.ndim < 2:
            kind = "rep"
        div_last = leaf.ndim >= 1 and shape[-1] % nmodel == 0
        div_row = leaf.ndim >= 2 and shape[-2] % nmodel == 0
        if policy.fsdp:
            # FSDP dim must also divide
            if kind == "col" and leaf.ndim >= 2 and shape[-2] % ndata != 0:
                div_row = False  # (unused for col, kept for clarity)
            if kind == "col" and shape[-2] % ndata != 0:
                kind_spec = _spec_for(kind, leaf.ndim, ShardingPolicy(fsdp=False), div_last, div_row)
                return NamedSharding(mesh, kind_spec)
            if kind == "row" and shape[-1] % ndata != 0:
                kind_spec = _spec_for(kind, leaf.ndim, ShardingPolicy(fsdp=False), div_last, div_row)
                return NamedSharding(mesh, kind_spec)
            if kind == "vocab" and (shape[0] % nmodel or shape[1] % ndata):
                return NamedSharding(mesh, P(*[None] * leaf.ndim))
        if kind == "vocab" and shape[0] % nmodel:
            kind = "rep"
        return NamedSharding(mesh, _spec_for(kind, leaf.ndim, policy, div_last, div_row))

    return jax.tree_util.tree_map_with_path(assign, params_spec)


def lora_shardings(lora_spec: PyTree, mesh: Mesh,
                   policy: ShardingPolicy = ShardingPolicy()) -> PyTree:
    # adapters are O(r x m): replicate (they are the paper's "switchable" state)
    return jax.tree.map(lambda l: NamedSharding(mesh, P(*[None] * l.ndim)),
                        lora_spec)


def batch_shardings(specs: dict, mesh: Mesh) -> dict:
    """Input batch: shard the batch dim over the dp axes when divisible."""
    import math
    dp = dp_axes(mesh)
    dp_total = math.prod(mesh.shape[a] for a in dp)

    def assign_leaf(leaf, batch_dim: int):
        spec = [None] * leaf.ndim
        if leaf.ndim > batch_dim and leaf.shape[batch_dim] % dp_total == 0:
            spec[batch_dim] = dp
        return NamedSharding(mesh, P(*spec))

    out = {}
    for key, val in specs.items():
        if key == "cache":
            out[key] = jax.tree.map(lambda l: assign_leaf(l, 1), val)
        elif key == "pos":
            out[key] = NamedSharding(mesh, P())
        else:
            out[key] = jax.tree.map(lambda l: assign_leaf(l, 0), val)
    return out


def hidden_constraint(mesh: Mesh, policy: ShardingPolicy):
    """with_sharding_constraint applied to the residual stream each layer."""
    import math
    dp = dp_axes(mesh)
    dp_total = math.prod(mesh.shape[a] for a in dp)
    nmodel = mesh.shape.get("model", 1)

    def constrain(x):
        if x.ndim == 3:
            bspec = dp if x.shape[0] % dp_total == 0 else None
            seq = "model" if (policy.seq_shard and x.shape[1] % nmodel == 0) else None
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(bspec, seq, None)))
        return x

    return constrain
