"""Pytree checkpointing: leaves -> msgpack of raw ndarray buffers
(zstd-compressed when ``zstandard`` is installed), structure -> path-keyed
(no pickle; robust across sessions).

Non-array state (event heaps, RNG stream positions, commit logs — the
discrete-event side of a mid-flight snapshot) rides the same pytree
format as a JSON blob packed into a uint8 leaf: ``pack_json`` /
``unpack_json``.  CPython's JSON float repr round-trips bit-exactly, so
the DES timeline survives a save/load unchanged.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:  # optional dependency: the ``zstd`` extra
    import zstandard
except ImportError:
    zstandard = None

PyTree = Any
_SEP = "\x1f"   # unit separator: never appears in our dict keys
_MAGIC_ZSTD = b"\x28\xb5\x2f\xfd"   # zstd frame header


def _compress(raw: bytes, level: int) -> bytes:
    if zstandard is None:
        return raw
    return zstandard.ZstdCompressor(level=level).compress(raw)


def _decompress(blob: bytes) -> bytes:
    if blob[:4] == _MAGIC_ZSTD:
        if zstandard is None:
            raise RuntimeError(
                "checkpoint is zstd-compressed but the 'zstandard' package is "
                "not installed (pip install repro[zstd])")
        return zstandard.ZstdDecompressor().decompress(blob)
    return blob


def pack_json(obj: Any) -> np.ndarray:
    """Encode a JSON-able object as a uint8 ndarray leaf.

    Floats round-trip bit-exactly (CPython ``repr`` is shortest-exact and
    ``json`` uses it); NaN/Infinity use the Python-extended literals, which
    ``unpack_json`` reads back.  Use for discrete-event/bookkeeping state
    that must live inside an array-leaf pytree checkpoint.

    >>> int(unpack_json(pack_json({"t": 1.5}))["t"] * 2)
    3
    """
    return np.frombuffer(json.dumps(obj).encode("utf-8"), np.uint8).copy()


def unpack_json(arr: Any) -> Any:
    """Inverse of :func:`pack_json` (accepts np or jax uint8 arrays)."""
    return json.loads(np.asarray(arr).tobytes().decode("utf-8"))


def codec() -> str:
    """Codec tag recorded for saves on this install."""
    return "zstd" if zstandard is not None else "raw"


def _flatten(tree: PyTree):
    flat = {}

    def walk(node, path):
        if isinstance(node, dict):
            for k in sorted(node.keys()):
                walk(node[k], path + [str(k)])
        elif isinstance(node, (list, tuple)):
            tag = "T" if isinstance(node, tuple) else "L"
            for i, v in enumerate(node):
                walk(v, path + [f"{tag}{i}"])
        else:
            flat[_SEP.join(path)] = np.asarray(node)

    walk(tree, [])
    return flat


def _unflatten(flat: dict) -> PyTree:
    root: dict = {}
    for key, val in flat.items():
        parts = key.split(_SEP)
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val

    def rebuild(node):
        if not isinstance(node, dict):
            return node
        keys = list(node.keys())
        if keys and all(k[0] in "TL" and k[1:].isdigit() for k in keys):
            seq = [rebuild(node[k]) for k in sorted(keys, key=lambda s: int(s[1:]))]
            return tuple(seq) if keys[0][0] == "T" else seq
        return {k: rebuild(v) for k, v in node.items()}

    return rebuild(root)


def save(path: str, tree: PyTree, level: int = 3) -> None:
    flat = _flatten(jax.device_get(tree))
    payload = {
        k: {"dtype": str(v.dtype), "shape": list(v.shape), "data": v.tobytes()}
        for k, v in flat.items()
    }
    payload["\x00codec"] = codec()
    raw = msgpack.packb(payload, use_bin_type=True)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(_compress(raw, level))
    os.replace(tmp, path)


def load(path: str, as_jax: bool = True) -> PyTree:
    with open(path, "rb") as f:
        raw = _decompress(f.read())
    payload = msgpack.unpackb(raw, raw=False)
    payload.pop("\x00codec", None)
    flat = {}
    for k, rec in payload.items():
        arr = np.frombuffer(rec["data"], dtype=np.dtype(rec["dtype"]))
        arr = arr.reshape(rec["shape"])
        # 64-bit leaves stay numpy: jnp.asarray silently truncates them to
        # 32 bits when jax_enable_x64 is off, which would corrupt the
        # bit-exact bookkeeping state (event timestamps, RNG words) a
        # mid-flight snapshot carries next to the float32 model weights
        if as_jax and arr.dtype not in (np.float64, np.int64, np.uint64):
            arr = jnp.asarray(arr)
        flat[k] = arr
    return _unflatten(flat)
