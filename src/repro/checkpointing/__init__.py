from repro.checkpointing.checkpoint import load, save
from repro.checkpointing.manager import CheckpointManager

__all__ = ["CheckpointManager", "load", "save"]
