"""Checkpointing: pickle-free pytree serialization (``checkpoint``),
rotation/retention/resume policy (``manager``), and the periodic
mid-flight snapshot policy the federation drivers attach to the event
clock (``PeriodicSnapshotter``; see docs/checkpointing.md)."""
from repro.checkpointing.checkpoint import load, pack_json, save, unpack_json
from repro.checkpointing.manager import (CheckpointManager,
                                         PeriodicSnapshotter, load_snapshot)

__all__ = ["CheckpointManager", "PeriodicSnapshotter", "load",
           "load_snapshot", "pack_json", "save", "unpack_json"]
