"""Checkpoint manager: rotation, best-metric retention, resume.

Used by the federated simulator (whole-fleet adapter/optimizer state) and
the central trainer. Files are the zstd-msgpack pytrees of checkpoint.py.

``PeriodicSnapshotter`` layers a simulated-time snapshot cadence on top:
the federation drivers call ``maybe_save(now, state_fn)`` from the clock's
tick callback, and a snapshot is written whenever ``now`` crosses the next
``every_s`` boundary — atomically (tmp + rename, via ``checkpoint.save``)
and with bounded retention (``keep_last`` rotation).  See
``docs/checkpointing.md`` for the snapshot format and resume guarantees.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Callable, Optional

from repro.checkpointing.checkpoint import load, save

PyTree = Any


class CheckpointManager:
    def __init__(self, directory: str, *, keep_last: int = 3,
                 keep_best: int = 1, metric_mode: str = "max"):
        self.dir = directory
        self.keep_last = keep_last
        self.keep_best = keep_best
        self.metric_mode = metric_mode
        os.makedirs(directory, exist_ok=True)
        self._index_path = os.path.join(directory, "index.json")
        self._index = {"steps": {}, "best": []}
        if os.path.exists(self._index_path):
            with open(self._index_path) as f:
                self._index = json.load(f)

    # ------------------------------------------------------------------ io
    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}.ckpt")

    def _flush_index(self):
        tmp = self._index_path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self._index, f)
        os.replace(tmp, self._index_path)

    def save(self, step: int, state: PyTree,
             metric: Optional[float] = None) -> str:
        path = self._path(step)
        save(path, state)
        self._index["steps"][str(step)] = {"path": path, "metric": metric}
        self._rotate(metric, step)
        self._flush_index()
        return path

    def _rotate(self, metric: Optional[float], step: int):
        # best list
        if metric is not None:
            best = self._index["best"]
            best.append([metric, step])
            rev = self.metric_mode == "max"
            best.sort(key=lambda x: x[0], reverse=rev)
            self._index["best"] = best[: self.keep_best]
        protected = {s for _, s in self._index["best"]}
        steps = sorted(int(s) for s in self._index["steps"])
        to_keep = set(steps[-self.keep_last:]) | protected
        for s in steps:
            if s not in to_keep:
                rec = self._index["steps"].pop(str(s))
                if os.path.exists(rec["path"]):
                    os.remove(rec["path"])

    # ------------------------------------------------------------------ read
    def latest_step(self) -> Optional[int]:
        steps = [int(s) for s in self._index["steps"]]
        return max(steps) if steps else None

    def best_step(self) -> Optional[int]:
        return self._index["best"][0][1] if self._index["best"] else None

    def restore(self, step: Optional[int] = None) -> PyTree:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        return load(self._index["steps"][str(step)]["path"])

    def all_steps(self):
        return sorted(int(s) for s in self._index["steps"])


class PeriodicSnapshotter:
    """Periodic mid-flight snapshot policy over a :class:`CheckpointManager`.

    ``every_s`` is SIMULATED seconds (the federation clock's timeline, not
    wall time): the first snapshot lands at the first tick at or past
    ``every_s``, the next at the following multiple, and so on.  Writes are
    atomic and rotated (``keep_last``); the snapshot counter continues from
    whatever the directory already holds, so a resumed run extends the same
    snapshot series instead of clobbering it.

    Taking a snapshot is a pure read of the run state — attaching a
    snapshotter can never perturb the simulated timeline (the kill-and-
    resume regression tests depend on exactly this).
    """

    def __init__(self, directory: str, every_s: float, *, keep_last: int = 3):
        if every_s <= 0:
            raise ValueError("every_s must be > 0")
        self.manager = CheckpointManager(directory, keep_last=keep_last)
        self.every_s = float(every_s)
        self.next_due = float(every_s)
        self._count = self.manager.latest_step() or 0

    def due(self, now: float) -> bool:
        """True when simulated instant ``now`` has crossed the next boundary."""
        return now >= self.next_due

    def fast_forward(self, now: float) -> None:
        """Advance the cadence past ``now`` without writing — call after
        restoring a snapshot so a resumed run continues the original
        schedule instead of re-snapshotting its own resume point."""
        while self.next_due <= now:
            self.next_due += self.every_s

    def maybe_save(self, now: float, state_fn: Callable[[], PyTree]
                   ) -> Optional[str]:
        """Snapshot if due; returns the written path (or None).  ``state_fn``
        is only invoked when a snapshot is actually taken."""
        if not self.due(now):
            return None
        self._count += 1
        while self.next_due <= now:
            self.next_due += self.every_s
        return self.manager.save(self._count, state_fn())


def load_snapshot(path: str) -> PyTree:
    """Load a snapshot from a checkpoint FILE or a snapshot DIRECTORY (the
    directory form resolves to the latest rotated snapshot via the index)."""
    if os.path.isdir(path):
        return CheckpointManager(path).restore()
    return load(path)
