"""Checkpoint manager: rotation, best-metric retention, resume.

Used by the federated simulator (whole-fleet adapter/optimizer state) and
the central trainer. Files are the zstd-msgpack pytrees of checkpoint.py.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Callable, Optional

from repro.checkpointing.checkpoint import load, save

PyTree = Any


class CheckpointManager:
    def __init__(self, directory: str, *, keep_last: int = 3,
                 keep_best: int = 1, metric_mode: str = "max"):
        self.dir = directory
        self.keep_last = keep_last
        self.keep_best = keep_best
        self.metric_mode = metric_mode
        os.makedirs(directory, exist_ok=True)
        self._index_path = os.path.join(directory, "index.json")
        self._index = {"steps": {}, "best": []}
        if os.path.exists(self._index_path):
            with open(self._index_path) as f:
                self._index = json.load(f)

    # ------------------------------------------------------------------ io
    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}.ckpt")

    def _flush_index(self):
        tmp = self._index_path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self._index, f)
        os.replace(tmp, self._index_path)

    def save(self, step: int, state: PyTree,
             metric: Optional[float] = None) -> str:
        path = self._path(step)
        save(path, state)
        self._index["steps"][str(step)] = {"path": path, "metric": metric}
        self._rotate(metric, step)
        self._flush_index()
        return path

    def _rotate(self, metric: Optional[float], step: int):
        # best list
        if metric is not None:
            best = self._index["best"]
            best.append([metric, step])
            rev = self.metric_mode == "max"
            best.sort(key=lambda x: x[0], reverse=rev)
            self._index["best"] = best[: self.keep_best]
        protected = {s for _, s in self._index["best"]}
        steps = sorted(int(s) for s in self._index["steps"])
        to_keep = set(steps[-self.keep_last:]) | protected
        for s in steps:
            if s not in to_keep:
                rec = self._index["steps"].pop(str(s))
                if os.path.exists(rec["path"]):
                    os.remove(rec["path"])

    # ------------------------------------------------------------------ read
    def latest_step(self) -> Optional[int]:
        steps = [int(s) for s in self._index["steps"]]
        return max(steps) if steps else None

    def best_step(self) -> Optional[int]:
        return self._index["best"][0][1] if self._index["best"] else None

    def restore(self, step: Optional[int] = None) -> PyTree:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        return load(self._index["steps"][str(step)]["path"])

    def all_steps(self):
        return sorted(int(s) for s in self._index["steps"])
