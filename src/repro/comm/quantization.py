"""Activation transport compression for the client<->server wireless links.

The paper's setting (100 Mbps) makes the activation upload T^fc and the
gradient download T^bc first-order terms of Eq. 10 (they dominate the
makespan on the §V fleet). This module implements the standard remedy the
paper cites as related work [10]: per-token symmetric int8 quantization with
error feedback — 4x fewer bytes on both links at negligible accuracy cost
(validated end-to-end in tests/test_comm.py and bench_ablations).

Layout: activations (B, S, d) are quantized per (B, S) row with an absmax
scale; the int8 payload + f32 scales are what crosses the "network".
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

Array = jax.Array


class Quantized(NamedTuple):
    q: Array        # int8 payload, same shape as the input
    scale: Array    # f32, input shape minus the last dim

    @property
    def nbytes(self) -> int:
        return self.q.size * 1 + self.scale.size * 4


def quantize(x: Array, *, axis: int = -1) -> Quantized:
    """Symmetric per-row int8: q = round(x / s), s = absmax/127."""
    xf = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xf), axis=axis, keepdims=True)
    scale = jnp.maximum(absmax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return Quantized(q=q, scale=jnp.squeeze(scale, axis=axis))


def dequantize(qx: Quantized, dtype=jnp.float32, *, axis: int = -1) -> Array:
    scale = jnp.expand_dims(qx.scale, axis)
    return (qx.q.astype(jnp.float32) * scale).astype(dtype)


def quantize_with_feedback(x: Array, residual: Optional[Array], *,
                           axis: int = -1):
    """Error-feedback quantization: the previous round's quantization error
    is added back before quantizing (EF-SGD style), so the bias does not
    accumulate across rounds.

    Returns (Quantized, new_residual)."""
    xf = x.astype(jnp.float32)
    if residual is not None:
        xf = xf + residual
    qx = quantize(xf, axis=axis)
    new_residual = xf - dequantize(qx, jnp.float32, axis=axis)
    return qx, new_residual


def transport_bytes(shape, quantized: bool, dtype_bytes: int = 4) -> float:
    """Wire bytes for an activation/gradient tensor of ``shape``."""
    import math
    n = math.prod(shape)
    if not quantized:
        return float(n * dtype_bytes)
    rows = math.prod(shape[:-1])
    return float(n * 1 + rows * 4)
