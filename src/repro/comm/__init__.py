from repro.comm.quantization import (Quantized, dequantize, quantize,
                                     quantize_with_feedback, transport_bytes)

__all__ = ["Quantized", "dequantize", "quantize", "quantize_with_feedback",
           "transport_bytes"]
