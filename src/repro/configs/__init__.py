"""Config registry: the 10 assigned architectures + the paper's BERT-base."""
from __future__ import annotations

from repro.configs.base import LoRAConfig, ModelConfig, MoEConfig, SSMConfig, reduced
from repro.configs.shapes import ASSIGNED_SHAPES, SHAPES, InputShape, get_shape

from repro.configs import (  # noqa: E402
    bert_base,
    gemma_2b,
    granite_3_2b,
    granite_20b,
    grok_1_314b,
    internvl2_26b,
    qwen1_5_4b,
    qwen3_moe_30b_a3b,
    rwkv6_3b,
    whisper_large_v3,
    zamba2_7b,
)

REGISTRY: dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        granite_20b, gemma_2b, granite_3_2b, grok_1_314b, whisper_large_v3,
        qwen1_5_4b, internvl2_26b, rwkv6_3b, qwen3_moe_30b_a3b, zamba2_7b,
        bert_base,
    )
}

ASSIGNED_ARCHS = tuple(n for n in REGISTRY if n != "bert-base")


def get_config(name: str) -> ModelConfig:
    try:
        return REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}") from None


__all__ = [
    "ASSIGNED_ARCHS", "ASSIGNED_SHAPES", "InputShape", "LoRAConfig",
    "ModelConfig", "MoEConfig", "REGISTRY", "SHAPES", "SSMConfig",
    "get_config", "get_shape", "reduced",
]
