"""Model configuration dataclasses shared by every architecture family."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # number of shared (always-on) experts, qwen-style; 0 for grok
    num_shared_experts: int = 0


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2-style SSD block parameters."""
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    # rwkv uses d_model//head_dim heads with (head_dim x head_dim) wkv state
    ddlerp_rank: int = 32   # rwkv6 data-dependent lerp low-rank
    decay_rank: int = 64    # rwkv6 decay low-rank


@dataclasses.dataclass(frozen=True)
class LoRAConfig:
    rank: int = 16
    alpha: float = 32.0
    # which projections carry adapters; names are matched against param paths
    targets: Tuple[str, ...] = ("wq", "wk", "wv", "wo")
    dropout: float = 0.0
    # how adapted projections execute: "einsum" (pure-jnp oracle) or
    # "fused" (Pallas kernels — fused per-client, grouped for ragged
    # cohorts; see models/layers.lora_apply)
    impl: str = "einsum"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str              # dense | moe | ssm | hybrid | encdec | vlm | encoder
    n_layers: int
    d_model: int
    n_heads: int             # 0 for attention-free families
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0        # 0 -> d_model // n_heads
    activation: str = "silu"     # silu | geglu | gelu
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    tie_embeddings: bool = True
    positional: str = "rope"     # rope | learned | none
    max_position: int = 1 << 20  # learned-position table size cap
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): a single shared attention+MLP block applied every k blocks
    shared_attn_every: int = 0
    # encoder-decoder (whisper): encoder depth + fixed frame count
    n_encoder_layers: int = 0
    encoder_seq: int = 0
    # vlm (internvl): vision token prefix produced by a stubbed ViT
    n_vision_tokens: int = 0
    vision_embed_dim: int = 0
    # long-context variant: sliding-window attention (None = full attention)
    sliding_window: Optional[int] = None
    # classification head (bert / the paper's CARER task); 0 = LM head
    n_classes: int = 0
    causal: bool = True
    lora: LoRAConfig = dataclasses.field(default_factory=LoRAConfig)
    dtype: str = "bfloat16"
    # execution variants (§Perf knobs; defaults = paper-faithful baseline)
    attn_impl: str = "naive"     # naive (materialized probs) | chunked (online softmax)
    attn_chunk: int = 1024
    wkv_impl: str = "scan"       # scan (per-step state IO) | chunked (per-chunk)
    wkv_chunk: int = 16
    moe_token_chunks: int = 1    # >1: scan expert dispatch over token blocks
                                 # (smaller live capacity buffers; §Perf)
    embed_impl: str = "gather"   # gather | onehot (sharding-friendly matmul)
    kv_cache_dtype: str = "model"  # model | int8 (quantized decode cache)
    # MoE dispatch groups (0 -> one group per data shard, set at lowering time)
    moe_groups: int = 0
    source: str = ""         # citation for the assigned config

    def __post_init__(self):
        if self.n_heads:
            hd = self.head_dim or self.d_model // self.n_heads
            object.__setattr__(self, "head_dim", hd)
            if self.n_heads % max(self.n_kv_heads, 1):
                raise ValueError(f"{self.name}: n_heads={self.n_heads} not divisible by n_kv_heads={self.n_kv_heads}")

    # ---- derived quantities -------------------------------------------------
    @property
    def attn_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Analytical parameter count (embeddings + blocks + head)."""
        d, ff, V, L = self.d_model, self.d_ff, self.vocab_size, self.n_layers
        n = V * d  # embedding
        if not self.tie_embeddings and self.n_classes == 0:
            n += V * d
        if self.n_classes:
            n += d * self.n_classes
        if self.positional == "learned":
            n += self.max_position * d

        def attn_block(heads=True):
            a = d * self.attn_dim + 2 * d * self.kv_dim + self.attn_dim * d
            if self.qkv_bias:
                a += self.attn_dim + 2 * self.kv_dim
            return a

        def mlp_block(ffx):
            gated = self.activation in ("silu", "geglu")
            return (3 if gated else 2) * d * ffx

        if self.family in ("dense", "vlm", "encoder"):
            n += L * (attn_block() + mlp_block(ff) + 2 * d)
            if self.family == "vlm":
                n += self.vision_embed_dim * d  # projector
        elif self.family == "moe":
            m = self.moe
            expert = mlp_block(m.d_ff_expert)
            n += L * (attn_block() + d * m.num_experts + m.num_experts * expert
                      + m.num_shared_experts * mlp_block(ff) + 2 * d)
        elif self.family == "ssm":  # rwkv6
            # time-mix: r,k,v,g,o (5 d*d) + ddlerp + decay low-rank + channel mix (~3.5 d*d)
            s = self.ssm
            n += L * (5 * d * d + 5 * s.ddlerp_rank * 2 * d + 2 * s.decay_rank * d
                      + 2 * d * int(3.5 * d) + 4 * d)
        elif self.family == "hybrid":
            s = self.ssm
            d_in = s.expand * d
            mamba = d * (2 * d_in + 2 * s.d_state * (d_in // s.head_dim) * 0 + 2) \
                + d * d_in + d_in * d  # in/out proj approx
            n += L * (mamba + 2 * d)
            n += attn_block() + mlp_block(ff) + 2 * d  # one shared block
        elif self.family == "encdec":
            enc = attn_block() + mlp_block(ff) + 2 * d
            dec = 2 * attn_block() + mlp_block(ff) + 3 * d
            n += self.n_encoder_layers * enc + L * dec
        return n

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: routed top-k only)."""
        if self.family != "moe":
            return self.param_count()
        m = self.moe
        d = self.d_model
        gated = self.activation in ("silu", "geglu")
        per_expert = (3 if gated else 2) * d * m.d_ff_expert
        dense_part = self.param_count() - self.n_layers * m.num_experts * per_expert
        return dense_part + self.n_layers * m.top_k * per_expert


def reduced(cfg: ModelConfig, *, n_layers: int = 2, d_model: int = 256,
            seq_cap: int = 128) -> ModelConfig:
    """Smoke-test variant: same family/topology, tiny dims (<=512 d_model, <=4 experts)."""
    assert d_model <= 512
    if cfg.n_heads:
        n_kv = min(cfg.n_kv_heads, 4)
        n_heads = max(4, n_kv)
        head_dim = d_model // n_heads
    else:
        n_kv = n_heads = 0
        head_dim = 0
    kw = dict(
        n_layers=n_layers, d_model=d_model, n_heads=n_heads, n_kv_heads=n_kv,
        head_dim=head_dim, d_ff=d_model * 4, vocab_size=min(cfg.vocab_size, 512),
        max_position=4096, dtype="float32",
    )
    if cfg.moe:
        kw["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, top_k=min(cfg.moe.top_k, 2), d_ff_expert=d_model)
    if cfg.ssm:
        kw["ssm"] = dataclasses.replace(cfg.ssm, d_state=16, head_dim=32, ddlerp_rank=8, decay_rank=16)
    if cfg.shared_attn_every:
        kw["shared_attn_every"] = 2
    if cfg.n_encoder_layers:
        kw["n_encoder_layers"] = n_layers
        kw["encoder_seq"] = 16
    if cfg.n_vision_tokens:
        kw["n_vision_tokens"] = 8
        kw["vision_embed_dim"] = d_model
    if cfg.sliding_window:
        kw["sliding_window"] = min(cfg.sliding_window, seq_cap)
    kw["lora"] = dataclasses.replace(cfg.lora, rank=4, alpha=8.0)
    return cfg.with_(**kw)
