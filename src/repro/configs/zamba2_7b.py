"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention block. [arXiv:2411.15242]"""
from repro.configs.base import LoRAConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,            # mamba2 blocks
    d_model=3584,
    n_heads=32,             # shared attention block
    n_kv_heads=32,
    head_dim=112,
    d_ff=14336,             # shared block MLP
    vocab_size=32_000,
    activation="silu",
    norm="rmsnorm",
    rope_theta=10_000.0,
    tie_embeddings=True,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64),
    shared_attn_every=6,    # one shared attn+MLP block re-applied every 6 mamba blocks
    # long_500k applies sliding_window=8192 to the shared attention (launch layer)
    lora=LoRAConfig(rank=16, alpha=32.0, targets=("in_proj", "out_proj", "wq", "wk", "wv", "wo")),
    source="arXiv:2411.15242 (Zamba2-7B)",
)
