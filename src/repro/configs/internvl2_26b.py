"""internvl2-26b [vlm] — InternViT (stub) + InternLM2-20B backbone. [arXiv:2404.16821]

The ViT + MLP projector frontend is the permitted stub: ``input_specs()``
supplies precomputed patch embeddings of shape (B, n_vision_tokens,
vision_embed_dim); the framework implements the projector + language model.
"""
from repro.configs.base import LoRAConfig, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92_553,
    activation="silu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    n_vision_tokens=1024,       # 448x448 image -> 1024 patch tokens after pixel shuffle
    vision_embed_dim=3200,      # InternViT-6B hidden size
    lora=LoRAConfig(rank=16, alpha=32.0, targets=("wq", "wk", "wv", "wo")),
    source="arXiv:2404.16821 (InternVL2-26B: InternViT-6B + InternLM2-20B)",
)
