"""rwkv6-3b [ssm] — RWKV-6 "Finch": attention-free, data-dependent decay.
[arXiv:2404.05892]"""
from repro.configs.base import LoRAConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=0,              # attention-free
    n_kv_heads=0,
    d_ff=8960,              # channel-mix hidden dim
    vocab_size=65_536,
    activation="relu2",     # channel-mix uses relu^2
    norm="layernorm",
    positional="none",
    tie_embeddings=True,
    ssm=SSMConfig(head_dim=64, ddlerp_rank=32, decay_rank=64),
    lora=LoRAConfig(rank=16, alpha=32.0, targets=("wr", "wk", "wv", "wg", "wo")),
    source="arXiv:2404.05892 (RWKV-6 Finch, 3B)",
)
