"""qwen3-moe-30b-a3b [moe] — 128 experts top-8, GQA kv=4. [hf:Qwen/Qwen3-30B-A3B]"""
from repro.configs.base import LoRAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=768,               # per-expert FFN dim
    vocab_size=151_936,
    activation="silu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=768, capacity_factor=1.25),
    lora=LoRAConfig(rank=16, alpha=32.0, targets=("wq", "wk", "wv", "wo", "wr_router")),
    source="hf:Qwen/Qwen3-30B-A3B",
)
