"""whisper-large-v3 [audio] — enc-dec backbone; conv/mel frontend is a stub
that supplies precomputed frame embeddings. [arXiv:2212.04356]"""
from repro.configs.base import LoRAConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    n_layers=32,            # decoder layers
    n_encoder_layers=32,
    encoder_seq=1500,       # 30 s of audio at 50 Hz after the conv stub
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,          # MHA
    head_dim=64,
    d_ff=5120,
    vocab_size=51_866,
    activation="gelu",
    norm="layernorm",
    positional="learned",
    max_position=32_768,    # decoder-side learned positions (448 in the original;
                            # enlarged so the assigned 32k shapes lower — DESIGN.md §10)
    tie_embeddings=True,
    lora=LoRAConfig(rank=16, alpha=32.0, targets=("wq", "wk", "wv", "wo")),
    source="arXiv:2212.04356 (Whisper large-v3)",
)
