"""bert-base [encoder] — the paper's own pre-trained model (Devlin 2018),
fine-tuned on a CARER-style 6-class emotion task with LoRA r=16."""
from repro.configs.base import LoRAConfig, ModelConfig

CONFIG = ModelConfig(
    name="bert-base",
    family="encoder",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=30_522,
    activation="gelu",
    norm="layernorm",
    positional="learned",
    max_position=512,
    causal=False,
    tie_embeddings=True,
    n_classes=6,            # CARER: sadness/joy/love/anger/fear/surprise
    dtype="float32",        # the paper fine-tunes in fp32 on the RTX 4080s
    lora=LoRAConfig(rank=16, alpha=32.0, targets=("wq", "wk", "wv", "wo")),
    source="arXiv:1810.04805 (BERT-base); paper §V simulation setup",
)
