"""grok-1-314b [moe] — 8 experts top-2, GQA kv=8. [hf:xai-org/grok-1]"""
from repro.configs.base import LoRAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131_072,
    activation="geglu",
    norm="rmsnorm",
    rope_theta=10_000.0,
    tie_embeddings=True,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=32768, capacity_factor=1.25),
    lora=LoRAConfig(rank=16, alpha=32.0, targets=("wq", "wk", "wv", "wo", "wr_router")),
    source="hf:xai-org/grok-1",
)
