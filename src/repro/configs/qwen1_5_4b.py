"""qwen1.5-4b [dense] — MHA with QKV bias. [hf:Qwen/Qwen1.5-0.5B family]"""
from repro.configs.base import LoRAConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,          # MHA
    head_dim=128,
    d_ff=6912,
    vocab_size=151_936,
    activation="silu",
    norm="rmsnorm",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    lora=LoRAConfig(rank=16, alpha=32.0, targets=("wq", "wk", "wv", "wo")),
    source="hf:Qwen/Qwen1.5-0.5B (scaled per assignment: 4B)",
)
