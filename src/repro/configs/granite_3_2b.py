"""granite-3-2b [dense] — GQA kv=8. [hf:ibm-granite/granite-3.0-2b-base]"""
from repro.configs.base import LoRAConfig, ModelConfig

CONFIG = ModelConfig(
    name="granite-3-2b",
    family="dense",
    n_layers=40,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=49_155,
    activation="silu",
    norm="rmsnorm",
    rope_theta=10_000.0,
    tie_embeddings=True,
    lora=LoRAConfig(rank=16, alpha=32.0, targets=("wq", "wk", "wv", "wo")),
    source="hf:ibm-granite/granite-3.0-2b-base",
)
