"""granite-20b [dense] — llama-style code model, MQA. [arXiv:2405.04324]"""
from repro.configs.base import LoRAConfig, ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,          # MQA
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    activation="gelu",      # non-gated MLP (gpt_bigcode lineage) -> 20B total
    norm="rmsnorm",
    rope_theta=10_000.0,
    tie_embeddings=True,
    lora=LoRAConfig(rank=16, alpha=32.0, targets=("wq", "wk", "wv", "wo")),
    source="arXiv:2405.04324 (Granite Code Models, 20B)",
)
