"""Assigned input shapes (public pool) + the paper's own workload shape.

Each shape names the step kind that the dry-run lowers:
  * train_*    -> ``train_step``   (forward + backward + LoRA/optimizer update)
  * prefill_*  -> ``prefill_step`` (forward, build KV/recurrent cache)
  * decode_*   -> ``serve_step``   (ONE new token against a cache of seq_len)
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def step_name(self) -> str:
        return {"train": "train_step", "prefill": "prefill_step", "decode": "serve_step"}[self.kind]


TRAIN_4K = InputShape("train_4k", seq_len=4_096, global_batch=256, kind="train")
PREFILL_32K = InputShape("prefill_32k", seq_len=32_768, global_batch=32, kind="prefill")
DECODE_32K = InputShape("decode_32k", seq_len=32_768, global_batch=128, kind="decode")
LONG_500K = InputShape("long_500k", seq_len=524_288, global_batch=1, kind="decode")

# The paper's own fine-tuning workload (BERT-base, CARER): seq 128, batch 16.
PAPER_FT = InputShape("paper_ft", seq_len=128, global_batch=16, kind="train")

SHAPES: dict[str, InputShape] = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K, PAPER_FT)
}

ASSIGNED_SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def get_shape(name: str) -> InputShape:
    try:
        return SHAPES[name]
    except KeyError:
        raise KeyError(f"unknown input shape {name!r}; known: {sorted(SHAPES)}") from None
