"""gemma-2b [dense] — GeGLU, head_dim=256, MQA. [arXiv:2403.08295]"""
from repro.configs.base import LoRAConfig, ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,          # MQA on the 2b variant
    head_dim=256,
    d_ff=16384,
    vocab_size=256_000,
    activation="geglu",
    norm="rmsnorm",
    rope_theta=10_000.0,
    tie_embeddings=True,
    lora=LoRAConfig(rank=16, alpha=32.0, targets=("wq", "wk", "wv", "wo")),
    source="arXiv:2403.08295 (Gemma, 2B)",
)
