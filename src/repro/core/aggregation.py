"""Eqs. 6-9: dataset-size-weighted FedAvg of the full LoRA adapter lists,
aggregating each A and each B matrix separately, then re-splitting at every
client's (heterogeneous) cut point.

Beyond the paper's synchronous Eq. 6-8 weights, this module also carries the
async aggregation policy layer of the continuous-time engine: explicit-weight
aggregation (:func:`aggregate_full_weighted`), polynomial staleness
discounting of the Eq. 6-8 weights (:func:`staleness_weights`, the
``(1+s)^-alpha`` family of async FL), and the anchored merge that folds a
partial contributor buffer into the standing global adapters
(:func:`merge_into_global`).
"""
from __future__ import annotations

from typing import Any, List, Sequence

import jax
import jax.numpy as jnp

from repro.core import lora as lora_lib

PyTree = Any


def normalize_weights(weights: Sequence[float]) -> List[float]:
    ws = [float(w) for w in weights]
    if any(w < 0 for w in ws):
        raise ValueError("aggregation weights must be non-negative")
    total = sum(ws)
    if total <= 0.0:
        raise ValueError("aggregation weights must sum to > 0")
    return [w / total for w in ws]


def aggregate_full_weighted(full_loras: Sequence[PyTree],
                            weights: Sequence[float]) -> PyTree:
    """Leaf-wise convex combination of same-structure full adapter trees
    with explicit (not necessarily normalized) non-negative weights."""
    if len(full_loras) != len(weights):
        raise ValueError("one weight per adapter tree required")
    ws = normalize_weights(weights)

    def wsum(*leaves):
        acc = ws[0] * leaves[0].astype(jnp.float32)
        for w, leaf in zip(ws[1:], leaves[1:]):
            acc = acc + w * leaf.astype(jnp.float32)
        return acc.astype(leaves[0].dtype)

    return jax.tree.map(wsum, *full_loras)


def aggregate_full(full_loras: Sequence[PyTree], data_sizes: Sequence[int]) -> PyTree:
    """Eqs. 6-7: A_n = sum_u |D_u|/|D| * A_n^u ; B_n likewise (separately).

    Leaf-wise weighted mean over clients — valid because every R_f^u covers
    the full depth (that is the point of the paper's assemble-then-aggregate).
    """
    if len(full_loras) != len(data_sizes):
        raise ValueError("one data size per client required")
    return aggregate_full_weighted(full_loras, [float(d) for d in data_sizes])


def staleness_discount(staleness: int, alpha: float) -> float:
    """Polynomial staleness discount ``(1 + s)^-alpha``: a contribution
    computed against a model ``s`` commits old counts proportionally less.
    ``alpha = 0`` disables discounting (the ``buffered`` policy)."""
    if staleness < 0:
        raise ValueError("staleness must be >= 0")
    if alpha < 0:
        raise ValueError("staleness_alpha must be >= 0")
    return float((1.0 + staleness) ** (-alpha))


def staleness_weights(data_sizes: Sequence[int], staleness: Sequence[int],
                      alpha: float) -> List[float]:
    """Eq. 6-8 dataset-size weights, discounted per contributor by its
    staleness and renormalized to sum to one."""
    if len(data_sizes) != len(staleness):
        raise ValueError("one staleness value per contributor required")
    raw = [float(d) * staleness_discount(s, alpha)
           for d, s in zip(data_sizes, staleness)]
    return normalize_weights(raw)


def composed_staleness_discount(client_staleness: int, edge_staleness: int,
                                alpha: float) -> float:
    """Two-tier staleness discount: a contribution that was ``s_c`` versions
    old when its EDGE merged it, inside an edge summary that was ``s_e``
    versions old when the CLOUD merged that, discounts multiplicatively —
    ``(1+s_c)^-alpha * (1+s_e)^-alpha``.  Each tier applies the same
    polynomial family it would apply alone, so a zero-staleness tier is the
    identity and the flat (single-tier) discount is the ``s_e = 0`` case."""
    return (staleness_discount(client_staleness, alpha)
            * staleness_discount(edge_staleness, alpha))


def hierarchical_aggregate(full_loras: Sequence[PyTree],
                           weights: Sequence[float],
                           cells: Sequence[Sequence[int]]):
    """Two-tier Eq. 6-8: each edge cell partially merges its members'
    full-depth adapters with the members' data-size weights, then the cloud
    merges the edge summaries weighted by each cell's total data mass.

    ``cells`` holds member INDICES into ``full_loras`` (a partition of the
    contributors; cells with no contributing member may be omitted).  The
    two-level weighted mean telescopes to the flat Eq. 6-8 weighted mean —
    total client weight is conserved (to float tolerance, since each tier
    normalizes in float32) — which the property tests pin down.

    Returns ``(aggregated_full, edge_summaries, edge_weights)`` so callers
    can keep per-edge partials (for staleness bookkeeping or edge-local
    serving) alongside the cloud adapter.
    """
    if len(full_loras) != len(weights):
        raise ValueError("one weight per adapter tree required")
    idx_seen = [i for cell in cells for i in cell]
    if len(set(idx_seen)) != len(idx_seen):
        raise ValueError("edge cells must not share contributors")
    if set(idx_seen) != set(range(len(full_loras))):
        raise ValueError("edge cells must cover every contributor exactly "
                         "once")
    summaries, cell_masses = [], []
    for cell in cells:
        if not cell:
            continue
        cell_w = [float(weights[i]) for i in cell]
        summaries.append(aggregate_full_weighted(
            [full_loras[i] for i in cell], cell_w))
        cell_masses.append(sum(cell_w))
    agg = aggregate_full_weighted(summaries, cell_masses)
    return agg, summaries, cell_masses


def merge_into_global(global_full: PyTree, contrib_fulls: Sequence[PyTree],
                      contrib_weights: Sequence[float],
                      anchor_weight: float) -> PyTree:
    """Async commit: fold a buffer of contributor adapters into the standing
    global adapters.  ``anchor_weight`` is the data mass NOT represented in
    the buffer — the stale global stands in for the absent clients, so a
    full-cohort zero-staleness commit degenerates to exact Eq. 6-8 FedAvg.
    """
    if anchor_weight < 0:
        raise ValueError("anchor_weight must be >= 0")
    if not contrib_fulls:
        raise ValueError("need at least one contribution to merge")
    return aggregate_full_weighted(
        [global_full] + list(contrib_fulls),
        [float(anchor_weight)] + [float(w) for w in contrib_weights])


def aggregation_round(client_loras: Sequence[PyTree],
                      server_loras: Sequence[PyTree],
                      cuts: Sequence[int],
                      data_sizes: Sequence[int]):
    """One full aggregation phase (Alg. 1 lines 17-30).

    1. assemble R_f^u = {R_c^u, R_s^u}           (Eq. 5)
    2. aggregate A_n / B_n separately            (Eqs. 6-8)
    3. re-split at each client's own cut point   (Eq. 9)

    Returns (new_client_loras, new_server_loras, aggregated_full).
    """
    fulls = [lora_lib.assemble_full(c, s, k)
             for c, s, k in zip(client_loras, server_loras, cuts)]
    agg = aggregate_full(fulls, data_sizes)
    new_clients, new_servers = [], []
    for cut in cuts:
        c, s = lora_lib.split_lora(agg, cut)
        new_clients.append(c)
        new_servers.append(s)
    return new_clients, new_servers, agg


def anchored_hierarchical_aggregate(global_full: PyTree,
                                    contrib_fulls: Sequence[PyTree],
                                    contrib_weights: Sequence[float],
                                    cells: Sequence[Sequence[int]],
                                    cell_absent_mass: Sequence[float]):
    """Two-tier anchored merge for sampled cohorts at population scale.

    Each edge cell merges its CONTRIBUTING members (indices into
    ``contrib_fulls``) with the standing global anchoring that cell's
    absent data mass, then the cloud merges the cell summaries by total
    cell mass — the O(cohort) counterpart of folding every absent client's
    (untouched == global) adapters through :func:`hierarchical_aggregate`.
    Because each absent member's tree IS the global, both tiers telescope
    to the same weighted mean; the aggregation property tests pin the
    float-tolerance equivalence and the exact degenerate cases (no absent
    mass, or no contributors at all).

    Returns ``(aggregated_full, summaries, cell_masses)`` like
    :func:`hierarchical_aggregate`; cells with neither contributors nor
    absent mass are skipped.
    """
    if len(cells) != len(cell_absent_mass):
        raise ValueError("one absent-mass entry per cell required")
    idx_seen = [i for cell in cells for i in cell]
    if len(set(idx_seen)) != len(idx_seen):
        raise ValueError("edge cells must not share contributors")
    if set(idx_seen) != set(range(len(contrib_fulls))):
        raise ValueError("edge cells must cover every contributor exactly "
                         "once")
    summaries, cell_masses = [], []
    for cell, absent in zip(cells, cell_absent_mass):
        absent = float(absent)
        if absent < 0:
            raise ValueError("cell_absent_mass must be >= 0")
        ws = [float(contrib_weights[i]) for i in cell]
        if absent > 0:
            summaries.append(aggregate_full_weighted(
                [global_full] + [contrib_fulls[i] for i in cell],
                [absent] + ws))
        elif cell:
            summaries.append(aggregate_full_weighted(
                [contrib_fulls[i] for i in cell], ws))
        else:
            continue
        cell_masses.append(absent + sum(ws))
    agg = aggregate_full_weighted(summaries, cell_masses)
    return agg, summaries, cell_masses
