"""Eqs. 6-9: dataset-size-weighted FedAvg of the full LoRA adapter lists,
aggregating each A and each B matrix separately, then re-splitting at every
client's (heterogeneous) cut point.
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.core import lora as lora_lib

PyTree = Any


def aggregate_full(full_loras: Sequence[PyTree], data_sizes: Sequence[int]) -> PyTree:
    """Eqs. 6-7: A_n = sum_u |D_u|/|D| * A_n^u ; B_n likewise (separately).

    Leaf-wise weighted mean over clients — valid because every R_f^u covers
    the full depth (that is the point of the paper's assemble-then-aggregate).
    """
    if len(full_loras) != len(data_sizes):
        raise ValueError("one data size per client required")
    total = float(sum(data_sizes))
    ws = [float(d) / total for d in data_sizes]

    def wsum(*leaves):
        acc = ws[0] * leaves[0].astype(jnp.float32)
        for w, leaf in zip(ws[1:], leaves[1:]):
            acc = acc + w * leaf.astype(jnp.float32)
        return acc.astype(leaves[0].dtype)

    return jax.tree.map(wsum, *full_loras)


def aggregation_round(client_loras: Sequence[PyTree],
                      server_loras: Sequence[PyTree],
                      cuts: Sequence[int],
                      data_sizes: Sequence[int]):
    """One full aggregation phase (Alg. 1 lines 17-30).

    1. assemble R_f^u = {R_c^u, R_s^u}           (Eq. 5)
    2. aggregate A_n / B_n separately            (Eqs. 6-8)
    3. re-split at each client's own cut point   (Eq. 9)

    Returns (new_client_loras, new_server_loras, aggregated_full).
    """
    fulls = [lora_lib.assemble_full(c, s, k)
             for c, s, k in zip(client_loras, server_loras, cuts)]
    agg = aggregate_full(fulls, data_sizes)
    new_clients, new_servers = [], []
    for cut in cuts:
        c, s = lora_lib.split_lora(agg, cut)
        new_clients.append(c)
        new_servers.append(s)
    return new_clients, new_servers, agg
