"""§IV training-order scheduling.

Alg. 2 (ours): serve clients in descending N_c^u / C_u — the clients whose
*client-side backward* will take longest get their activation gradients
first, hiding client compute + downlink under the server's sequential work.

Baselines (paper §V): FIFO (by activation arrival) and Workload-First
(largest server-side workload first), plus a brute-force optimal for tests.
"""
from __future__ import annotations

import itertools
from typing import List, Sequence

from repro.core.cost_model import StepTimes, makespan


def schedule_ours(n_client_layers: Sequence[int], compute: Sequence[float]) -> List[int]:
    """Alg. 2: sort u by N_c^u / C_u descending."""
    ratio = [n / c for n, c in zip(n_client_layers, compute)]
    return sorted(range(len(ratio)), key=lambda u: (-ratio[u], u))


def schedule_fifo(times: Sequence[StepTimes]) -> List[int]:
    """First-in-first-out on activation arrival time T^f + T^fc."""
    return sorted(range(len(times)), key=lambda u: (times[u].ready, u))


def schedule_workload_first(times: Sequence[StepTimes]) -> List[int]:
    """Largest server-side workload (T^s) first."""
    return sorted(range(len(times)), key=lambda u: (-times[u].t_s, u))


def schedule_bandwidth_aware(times: Sequence[StepTimes]) -> List[int]:
    """Bandwidth-aware: largest gradient-download + client-backward tail
    (T^bc + T^b) first.  Alg. 2 hides client BACKWARD under the server's
    sequential work using compute ratios only; once per-client links vary,
    the downlink is part of that same hideable tail — so order by the whole
    tail.  Offline form uses the NOMINAL t_bc; the event engines re-predict
    t_bc from the live network state at every dispatch (see
    ``fed.engine``'s net-aware "bw" discipline)."""
    return sorted(range(len(times)),
                  key=lambda u: (-(times[u].t_bc + times[u].t_b), u))


def schedule_optimal(times: Sequence[StepTimes], limit: int = 8) -> List[int]:
    """Exhaustive min-makespan (tests / small U only)."""
    n = len(times)
    if n > limit:
        raise ValueError(f"brute force capped at U={limit}")
    best, best_order = float("inf"), list(range(n))
    for perm in itertools.permutations(range(n)):
        span, _, _ = makespan(times, perm)
        if span < best - 1e-12:
            best, best_order = span, list(perm)
    return best_order


def alg2_priorities(n_client_layers: Sequence[int],
                    compute: Sequence[float]) -> List[float]:
    """Alg. 2's N_c^u / C_u as a per-client priority value — the online
    (event-engine) form of ``schedule_ours``: when the server frees, serve
    the arrived client with the largest ratio."""
    return [n / c for n, c in zip(n_client_layers, compute)]


def refresh_priorities(out: List[float], n_client_layers: Sequence[int],
                       compute: Sequence[float]) -> List[float]:
    """Recompute Alg. 2 priorities IN PLACE into ``out`` (the list object
    the FederationClock holds a reference to).  The control plane calls
    this after a cut re-assignment so the online ``priority`` discipline
    keeps ordering by the LIVE N_c^u / C_u ratios — a precomputed priority
    list would silently keep scheduling by the stale cuts."""
    out[:] = alg2_priorities(n_client_layers, compute)
    return out


SCHEDULERS = {
    "ours": None,        # needs (n_layers, compute); see resolve_order
    "fifo": schedule_fifo,
    "wf": schedule_workload_first,
    "bw": schedule_bandwidth_aware,
    "optimal": schedule_optimal,
}

# offline policy name -> (engine queue discipline, needs_priorities).
# "optimal" has no online form: its brute-force order is handed to the
# engine as a fixed ``order`` instead.
ONLINE_DISCIPLINES = {
    "ours": ("priority", True),
    "fifo": ("fifo", False),
    "wf": ("wf", False),
    "bw": ("bw", False),
}


def resolve_online(policy: str):
    """Map an offline scheduler name to its (queue discipline, needs_pri)
    pair for the event engine.  The continuous-time async engine admits ONLY
    these — a fixed precomputed order is meaningless when uploads from
    different local rounds interleave in the server queue."""
    if policy not in ONLINE_DISCIPLINES:
        raise KeyError(f"scheduler {policy!r} has no online queue-discipline "
                       f"form (choose from {sorted(ONLINE_DISCIPLINES)})")
    return ONLINE_DISCIPLINES[policy]


def resolve_order(policy: str, times: Sequence[StepTimes],
                  n_client_layers: Sequence[int],
                  compute: Sequence[float]) -> List[int]:
    if policy == "ours":
        return schedule_ours(n_client_layers, compute)
    if policy not in SCHEDULERS:
        raise KeyError(f"unknown scheduling policy {policy!r}")
    return SCHEDULERS[policy](times)
