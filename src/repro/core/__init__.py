# The paper's primary contribution: memory-efficient split federated
# learning — heterogeneous layer splitting (partition), single-copy server
# with sequential LoRA switching (splitfl), adapter aggregation with
# re-split (aggregation, lora), and training-order scheduling (scheduling),
# driven by the analytical cost/memory models of §IV-§V.
from repro.core import (aggregation, cost_model, lora, memory_model,
                        partition, scheduling, splitfl)

__all__ = ["aggregation", "cost_model", "lora", "memory_model", "partition",
           "scheduling", "splitfl"]
