"""LoRA adapter management for the split-federated framework.

The paper's notation (§II):  R = {A, B} per targeted module;
R_f^u = {R_c^u, R_s^u} is the depth-ordered full adapter list of client u
(Eq. 5).  Our adapters live in *stacked* pytrees whose leading axis is the
layer index, so the split at a cut point (Eq. 9) is a slice along axis 0 and
re-assembly is a concat — exact and loss-free for heterogeneous cuts.
"""
from __future__ import annotations

from typing import Any, Sequence, Tuple

import jax
import jax.numpy as jnp

PyTree = Any

# keys (per model family) holding layer-stacked, cut-splittable adapters
STACKED_KEYS = ("layers", "enc_layers")
# keys holding server-resident, non-splittable adapters
SERVER_ONLY_KEYS = ("shared", "dec_layers")


def split_lora(lora: PyTree, cut: int) -> Tuple[PyTree, PyTree]:
    """Eq. 9: R_i -> (R_c [layers < cut], R_s [layers >= cut]).

    The client part contains only the stacked prefix; the server part keeps
    the full structure (server-only subtrees stay with the server).
    """
    client, server = {}, {}
    for key, sub in lora.items():
        if key in STACKED_KEYS:
            client[key] = jax.tree.map(lambda a: a[:cut], sub)
            server[key] = jax.tree.map(lambda a: a[cut:], sub)
        else:
            server[key] = sub
    return client, server


def assemble_full(client: PyTree, server: PyTree, cut: int) -> PyTree:
    """Eq. 5: R_f^u = {R_c^u, R_s^u} — concat stacked parts at the cut."""
    full = {}
    for key, sub in server.items():
        if key in STACKED_KEYS:
            full[key] = jax.tree.map(
                lambda c, s: jnp.concatenate([c, s], axis=0), client[key], sub)
        else:
            full[key] = sub
    return full


def embed_in_full_shape(part: PyTree, full_spec: PyTree, cut: int,
                        side: str) -> PyTree:
    """Place a split part back into a full-length zero tree (the execution
    engine always indexes adapters by absolute layer id)."""
    out = {}
    for key, spec_sub in full_spec.items():
        if key in STACKED_KEYS:
            def place(spec_leaf, key=key):
                return jnp.zeros(spec_leaf.shape, spec_leaf.dtype)
            zeros = jax.tree.map(place, spec_sub)
            if key in part:
                if side == "client":
                    out[key] = jax.tree.map(
                        lambda z, p: jax.lax.dynamic_update_slice_in_dim(z, p.astype(z.dtype), 0, 0)
                        if p.shape[0] else z, zeros, part[key])
                else:
                    out[key] = jax.tree.map(
                        lambda z, p: jax.lax.dynamic_update_slice_in_dim(z, p.astype(z.dtype), cut, 0)
                        if p.shape[0] else z, zeros, part[key])
            else:
                out[key] = zeros
        else:
            if key in part:
                out[key] = part[key]
            else:
                out[key] = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec_sub)
    return out


def adapter_list(lora: PyTree):
    """Depth-ordered flat list of (path, A, B) pairs — the paper's
    {A_1,B_1,...,A_N,B_N} view. N = len(result)."""
    out = []

    def walk(node, path):
        if isinstance(node, dict):
            if set(node.keys()) == {"a", "b"}:
                out.append(("/".join(path), node["a"], node["b"]))
            else:
                for k in sorted(node.keys()):
                    walk(node[k], path + [k])

    walk(lora, [])
    return out


def count_adapters(lora: PyTree) -> int:
    n = 0
    for _, a, b in adapter_list(lora):
        lead = a.shape[0] if a.ndim == 3 else 1   # stacked (L, r, in)
        n += lead
    return n


def adapter_bytes(lora: PyTree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(lora))


def merge_lora(params: PyTree, lora: PyTree, scale: float) -> PyTree:
    """W' = W + scale * B A for every adapted weight (Eq. 1) — used for
    export / merged-inference equivalence tests."""
    def merge_into(pnode, lnode):
        if not isinstance(lnode, dict):
            return pnode
        out = dict(pnode)
        for key, lsub in lnode.items():
            if key not in pnode:
                continue
            if isinstance(lsub, dict) and set(lsub.keys()) == {"a", "b"}:
                w = pnode[key]
                a, b = lsub["a"], lsub["b"]
                if a.ndim == 3:   # stacked (L, r, in) x (L, out, r)
                    delta = jnp.einsum("lor,lri->lio", b, a)
                else:
                    delta = jnp.einsum("or,ri->io", b, a)
                out[key] = (w.astype(jnp.float32) + scale * delta).astype(w.dtype)
            elif isinstance(lsub, dict):
                out[key] = merge_into(pnode[key], lsub)
        return out

    return merge_into(params, lora)


def zeros_like_lora(lora: PyTree) -> PyTree:
    return jax.tree.map(jnp.zeros_like, lora)


def stack_trees(trees: Sequence[PyTree]) -> PyTree:
    """Stack same-structure pytrees along a new leading cohort axis — the
    batched server step advances one such stacked tree per cohort chunk."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def unstack_tree(tree: PyTree) -> list:
    """Inverse of :func:`stack_trees`: split the leading cohort axis back
    into a list of per-client pytrees."""
    n = jax.tree.leaves(tree)[0].shape[0]
    return [jax.tree.map(lambda a, i=i: a[i], tree) for i in range(n)]


def slice_stack(tree: PyTree, lo: int, hi: int) -> PyTree:
    return jax.tree.map(lambda a: a[lo:hi], tree)
