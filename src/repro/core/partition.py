"""Capacity-based model splitting (§III setup phase).

Before training, every client reports (memory, compute); the server
replicates a client-side submodel per client — the largest prefix of blocks
that fits the device's memory budget and keeps the client's per-step compute
below a latency envelope — and records the cut points.

The same feasibility arithmetic is re-used ONLINE by the control plane
(``repro.control``): when link fades or memory pressure make the setup-phase
assignment stale, the re-solver probes candidate cuts through
:func:`feasible_cut` with a precomputed ``ModelBytes`` so each probe is
cheap.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

from repro.configs.base import ModelConfig
from repro.core.cost_model import DeviceProfile, layer_fwd_flops_per_token
from repro.core.memory_model import ModelBytes, client_memory


def max_cut_for_memory(cfg: ModelConfig, device: DeviceProfile, batch: int,
                       seq_len: int, mem_fraction: float = 0.5,
                       dtype_bytes: int = 4,
                       mb: Optional[ModelBytes] = None) -> int:
    """Largest N_c^u whose client-side footprint fits mem_fraction of RAM.

    Returns 0 when not even one block fits (zero-budget edge); returns
    ``cfg.n_layers`` when every block fits.  ``mb`` takes a precomputed
    :func:`repro.core.memory_model.model_bytes` so repeated probes (the
    online re-solver) skip the shape tracing."""
    budget = device.mem_gb * (1024 ** 3) * mem_fraction
    best = 0
    for cut in range(1, cfg.n_layers + 1):
        if client_memory(cfg, cut, batch, seq_len, dtype_bytes, mb=mb) <= budget:
            best = cut
        else:
            break
    return best


def max_cut_for_compute(cfg: ModelConfig, device: DeviceProfile, batch: int,
                        seq_len: int, latency_budget_s: float = 30.0) -> int:
    """Largest N_c^u whose fwd+bwd stays within the latency envelope."""
    tokens = float(batch) * seq_len
    per_layer = 3.0 * tokens * layer_fwd_flops_per_token(cfg, seq_len) \
        / (device.tflops * 1e12 * device.utilization)
    if per_layer <= 0:
        return cfg.n_layers
    return max(0, min(cfg.n_layers, int(latency_budget_s / per_layer)))


def feasible_cut(cfg: ModelConfig, device: DeviceProfile, batch: int,
                 seq_len: int, *, mem_fraction: float = 0.5,
                 latency_budget_s: float = 30.0, dtype_bytes: int = 4,
                 mb: Optional[ModelBytes] = None) -> int:
    """Largest cut that is BOTH memory- and compute-feasible (unclamped;
    0 means nothing fits).  The setup-phase assignment and the online
    control-plane solver share this as their feasibility oracle."""
    return min(max_cut_for_memory(cfg, device, batch, seq_len, mem_fraction,
                                  dtype_bytes, mb=mb),
               max_cut_for_compute(cfg, device, batch, seq_len,
                                   latency_budget_s))


def cut_bounds(cfg: ModelConfig, device: DeviceProfile, batch: int,
               seq_len: int, *, min_cut: int = 1,
               max_cut: Optional[int] = None, mem_fraction: float = 0.5,
               latency_budget_s: float = 30.0, dtype_bytes: int = 4,
               mb: Optional[ModelBytes] = None) -> Tuple[int, int]:
    """Clamped ``(lo, hi)`` candidate-cut range for one device: the
    feasibility ceiling intersected with the caller's [min_cut, max_cut]
    window.  ``hi`` can equal ``lo`` (no freedom) but never undercut it —
    a device that fits nothing still trains ``min_cut`` layers, as the
    setup phase has always guaranteed."""
    max_cut = max_cut if max_cut is not None else cfg.n_layers - 1
    hi = feasible_cut(cfg, device, batch, seq_len, mem_fraction=mem_fraction,
                      latency_budget_s=latency_budget_s,
                      dtype_bytes=dtype_bytes, mb=mb)
    hi = min(max(hi, min_cut), max_cut)
    return min_cut, hi


def assign_cuts(cfg: ModelConfig, devices: Sequence[DeviceProfile], batch: int,
                seq_len: int, *, min_cut: int = 1, max_cut: int | None = None,
                mem_fraction: float = 0.5,
                latency_budget_s: float = 30.0) -> List[int]:
    """Per-device cut points: min(memory-feasible, compute-feasible), clamped."""
    cuts = []
    for dev in devices:
        _, hi = cut_bounds(cfg, dev, batch, seq_len, min_cut=min_cut,
                           max_cut=max_cut, mem_fraction=mem_fraction,
                           latency_budget_s=latency_budget_s)
        cuts.append(int(hi))
    return cuts
