"""Capacity-based model splitting (§III setup phase).

Before training, every client reports (memory, compute); the server
replicates a client-side submodel per client — the largest prefix of blocks
that fits the device's memory budget and keeps the client's per-step compute
below a latency envelope — and records the cut points.
"""
from __future__ import annotations

from typing import List, Sequence

from repro.configs.base import ModelConfig
from repro.core.cost_model import DeviceProfile, layer_fwd_flops_per_token
from repro.core.memory_model import client_memory


def max_cut_for_memory(cfg: ModelConfig, device: DeviceProfile, batch: int,
                       seq_len: int, mem_fraction: float = 0.5,
                       dtype_bytes: int = 4) -> int:
    """Largest N_c^u whose client-side footprint fits mem_fraction of RAM."""
    budget = device.mem_gb * (1024 ** 3) * mem_fraction
    best = 0
    for cut in range(1, cfg.n_layers + 1):
        if client_memory(cfg, cut, batch, seq_len, dtype_bytes) <= budget:
            best = cut
        else:
            break
    return best


def max_cut_for_compute(cfg: ModelConfig, device: DeviceProfile, batch: int,
                        seq_len: int, latency_budget_s: float = 30.0) -> int:
    """Largest N_c^u whose fwd+bwd stays within the latency envelope."""
    tokens = float(batch) * seq_len
    per_layer = 3.0 * tokens * layer_fwd_flops_per_token(cfg, seq_len) \
        / (device.tflops * 1e12 * device.utilization)
    if per_layer <= 0:
        return cfg.n_layers
    return max(0, min(cfg.n_layers, int(latency_budget_s / per_layer)))


def assign_cuts(cfg: ModelConfig, devices: Sequence[DeviceProfile], batch: int,
                seq_len: int, *, min_cut: int = 1, max_cut: int | None = None,
                mem_fraction: float = 0.5,
                latency_budget_s: float = 30.0) -> List[int]:
    """Per-device cut points: min(memory-feasible, compute-feasible), clamped."""
    max_cut = max_cut if max_cut is not None else cfg.n_layers - 1
    cuts = []
    for dev in devices:
        c = min(max_cut_for_memory(cfg, dev, batch, seq_len, mem_fraction),
                max_cut_for_compute(cfg, dev, batch, seq_len, latency_budget_s))
        cuts.append(int(min(max(c, min_cut), max_cut)))
    return cuts
