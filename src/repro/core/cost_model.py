"""Analytical time model (paper §IV, Eq. 10): per-client step time

    T_u = T_u^f + T_u^fc + T_u^w + T_u^s + T_u^bc + T_u^b

driven by real FLOP counts from the model config and the device profiles of
§V.  The container has no Jetsons/TPUs, so wall-clock terms for the
federated experiments come from this model (DESIGN.md §10); the scheduler
and the simulator both consume it.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    name: str
    tflops: float            # peak fp16/fp32-ish throughput, TFLOPS
    mem_gb: float            # usable memory for training
    utilization: float = 0.30  # achieved fraction of peak on transformer blocks


@dataclasses.dataclass(frozen=True)
class LinkProfile:
    """Scalar NOMINAL link rate — what the closed-form Eq. 10 model plans
    with.  Time-varying links live in ``repro.net`` (the network plane);
    a LinkProfile is the degenerate constant case."""
    rate_mbps: float = 100.0   # paper §V: 100 Mbps up/down

    def transfer_s(self, num_bytes: float) -> float:
        return num_bytes * 8.0 / (self.rate_mbps * 1e6)


#: wire bytes per element for the activation dtypes the configs use
DTYPE_BYTES = {"float32": 4, "bfloat16": 2, "float16": 2, "float64": 8}


def dtype_nbytes(dtype: str) -> int:
    try:
        return DTYPE_BYTES[dtype]
    except KeyError:
        raise KeyError(f"unknown activation dtype {dtype!r} "
                       f"(known: {sorted(DTYPE_BYTES)})") from None


# ---------------------------------------------------------------------------
# FLOPs accounting
# ---------------------------------------------------------------------------

def layer_param_count(cfg: ModelConfig) -> float:
    """Average parameters per block (active params for MoE routing)."""
    body = cfg.active_param_count()
    body -= cfg.vocab_size * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    if cfg.positional == "learned":
        body -= cfg.max_position * cfg.d_model
    if cfg.n_classes:
        body -= cfg.d_model * cfg.n_classes
    return max(body, 0) / max(cfg.n_layers + cfg.n_encoder_layers, 1)


def layer_fwd_flops_per_token(cfg: ModelConfig, seq_len: int) -> float:
    """2 FLOPs per param-MAC + the quadratic attention term (causal half)."""
    flops = 2.0 * layer_param_count(cfg)
    if cfg.n_heads:
        flops += 2.0 * seq_len * cfg.attn_dim  # qk^T + pv, causal averaged
    return flops


def head_fwd_flops_per_token(cfg: ModelConfig) -> float:
    out_dim = cfg.n_classes if cfg.n_classes else cfg.vocab_size
    return 2.0 * cfg.d_model * out_dim


def lora_flops_per_token_per_layer(cfg: ModelConfig,
                                   rank: Optional[int] = None) -> float:
    # two rank-r matmuls per adapted projection; coarse: 4 targets.
    # ``rank`` overrides cfg.lora.rank (the control plane's rank knob).
    r = cfg.lora.rank if rank is None else int(rank)
    return 2.0 * len(cfg.lora.targets) * r * 2 * cfg.d_model


BWD_FACTOR = 2.0   # backward ~ 2x forward (dgrad through frozen + LoRA wgrad)


@dataclasses.dataclass(frozen=True)
class StepTimes:
    """All Eq. 10 terms for one client (seconds); T^w filled by the scheduler.

    ``t_fc``/``t_bc`` are the NOMINAL-rate transfer durations the analytic
    closed form (``makespan``) and the offline schedulers plan with.
    ``fc_bytes``/``bc_bytes`` are the payload sizes those durations were
    derived from — the network plane (``repro.net``) integrates BYTES over
    its time-varying rates, so the event engines treat the byte counts as
    authoritative whenever a plane is attached and fall back to the nominal
    seconds otherwise (raw jobs built without payload sizes)."""
    t_f: float     # client-side forward
    t_fc: float    # activation upload (nominal-rate seconds)
    t_s: float     # server fwd+bwd for this client's remaining layers
    t_bc: float    # activation-gradient download (nominal-rate seconds)
    t_b: float     # client-side backward
    fc_bytes: float = 0.0   # uplink payload (0 = unknown, use t_fc)
    bc_bytes: float = 0.0   # downlink payload (0 = unknown, use t_bc)

    @property
    def ready(self) -> float:
        return self.t_f + self.t_fc

    def total(self, t_w: float) -> float:
        return self.t_f + self.t_fc + t_w + self.t_s + self.t_bc + self.t_b


def activation_bytes(cfg: ModelConfig, batch: int, seq_len: int,
                     dtype_bytes: Optional[int] = None) -> float:
    """Cut-activation payload; element width follows ``cfg.dtype`` unless
    overridden (bf16 halves the wireless bytes vs the old fp32 constant)."""
    if dtype_bytes is None:
        dtype_bytes = dtype_nbytes(cfg.dtype)
    return float(batch) * seq_len * cfg.d_model * dtype_bytes


def client_step_times(cfg: ModelConfig, cut: int, device: DeviceProfile,
                      server: DeviceProfile, link: LinkProfile,
                      batch: int, seq_len: int,
                      dtype_bytes: Optional[int] = None,
                      lora_rank: Optional[int] = None) -> StepTimes:
    """Eq. 10 terms for client u with N_c^u = cut layers.  ``lora_rank``
    overrides the config's adapter rank (the control plane evaluates
    candidate per-client ranks through here)."""
    tokens = float(batch) * seq_len
    lf = layer_fwd_flops_per_token(cfg, seq_len) \
        + lora_flops_per_token_per_layer(cfg, rank=lora_rank)
    n_total = cfg.n_layers + cfg.n_encoder_layers if cfg.family == "encdec" else cfg.n_layers
    n_server = n_total - cut

    c_flops = tokens * (lf * cut)                          # embed fwd negligible
    s_flops = tokens * (lf * n_server + head_fwd_flops_per_token(cfg))
    act = activation_bytes(cfg, batch, seq_len, dtype_bytes)

    t_f = c_flops / (device.tflops * 1e12 * device.utilization)
    t_b = BWD_FACTOR * t_f
    t_s = (1.0 + BWD_FACTOR) * s_flops / (server.tflops * 1e12 * server.utilization)
    return StepTimes(t_f=t_f, t_fc=link.transfer_s(act), t_s=t_s,
                     t_bc=link.transfer_s(act), t_b=t_b,
                     fc_bytes=act, bc_bytes=act)


def lora_upload_bytes(cfg: ModelConfig, cut: int, dtype_bytes: int = 4,
                      rank: Optional[int] = None) -> float:
    """Client-side adapter upload per aggregation round (Eq. 5 upload)."""
    r = cfg.lora.rank if rank is None else int(rank)
    per_layer = 0.0
    d = cfg.d_model
    for _ in cfg.lora.targets:
        per_layer += r * 2 * d * dtype_bytes
    return per_layer * cut


def migration_bytes(cfg: ModelConfig, old_cut: int, new_cut: int,
                    dtype_bytes: int = 4,
                    rank: Optional[int] = None) -> Tuple[float, float]:
    """Wire bytes to MOVE a client's cut point at a commit boundary.

    Growing the client prefix ships the extra frozen block weights plus
    their adapters DOWN to the client; shrinking ships the dropped blocks'
    adapter state UP (the frozen weights already live in the server's full
    model, so nothing heavy travels).  Returns ``(down_bytes, up_bytes)``
    — the control plane charges these through the network plane before
    accepting a re-assignment.
    """
    delta = int(new_cut) - int(old_cut)
    per_layer_adapters = lora_upload_bytes(cfg, 1, dtype_bytes, rank=rank)
    if delta > 0:
        per_layer_weights = layer_param_count(cfg) * dtype_bytes
        return (delta * (per_layer_weights + per_layer_adapters), 0.0)
    return (0.0, -delta * per_layer_adapters)


def chunked_service_time(service_times: Sequence[float],
                         efficiency: float = 1.0) -> float:
    """Server time for one cohort chunk.  A single client is the sequential
    baseline (exactly its t_s); a k>1 chunk runs as ONE batched vmapped
    dispatch whose FLOPs still add up, discounted by ``efficiency`` (the
    measured batching win — fewer dispatches, fuller kernels)."""
    if not 0.0 < efficiency <= 1.0:
        raise ValueError("efficiency must be in (0, 1]")
    ts = list(service_times)
    if len(ts) <= 1:
        return float(sum(ts))
    return float(efficiency * sum(ts))


def makespan(times: Sequence[StepTimes], order: Sequence[int]):
    """Pipeline semantics of Eqs. 10-12: the server is a single sequential
    resource; client u's job becomes available at ready_u; completion is
    server finish + grad download + client backward.  Returns
    (step_time, per-client completion list, per-client T^w list)."""
    t_server = 0.0
    completion = [0.0] * len(times)
    waits = [0.0] * len(times)
    for u in order:
        st = times[u]
        start = max(t_server, st.ready)
        waits[u] = start - st.ready
        t_server = start + st.t_s
        completion[u] = t_server + st.t_bc + st.t_b
    return max(completion), completion, waits
