"""Analytical memory accounting (server side, paper Table I).

Exact parameter/adapter byte counts come from ``jax.eval_shape`` over the
real model definitions; activation footprints use the standard
stored-tensors estimate for LoRA fine-tuning (intermediate activations must
be kept to backprop into the adapters — the >70% of full-FT memory the
paper cites [13]).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax

from repro.configs.base import ModelConfig
from repro.models import build_model

PyTree = Any

# stored activations per block per token, in units of d_model elements,
# for LoRA backprop through a transformer block (inputs of the adapted
# matmuls + residuals + norms + GELU buffers; attention probs counted
# separately). 12 matches torch-style eager training (calibrated so all
# three Table I rows land within ~3% of the paper's measurements).
ACT_FACTOR_BLOCK = 12.0
OPTIMIZER_STATES = 2   # AdamW m and v


def _bytes(tree: PyTree) -> int:
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree.leaves(jax.eval_shape(lambda: tree)))


def tree_bytes(tree: PyTree) -> int:
    leaves = jax.tree.leaves(tree)
    return sum(int(l.size) * l.dtype.itemsize for l in leaves)


@dataclasses.dataclass(frozen=True)
class ModelBytes:
    embed: int
    per_layer: int          # one block, params only
    head: int               # untied head / classifier + final norm
    lora_per_layer: int     # adapters for one block
    lora_extra: int         # server-only adapters (shared/dec)
    n_layers: int

    def params(self, n_layers: int | None = None) -> int:
        n = self.n_layers if n_layers is None else n_layers
        return self.embed + n * self.per_layer + self.head

    def lora(self, n_layers: int | None = None) -> int:
        n = self.n_layers if n_layers is None else n_layers
        return n * self.lora_per_layer + self.lora_extra


def model_bytes(cfg: ModelConfig) -> ModelBytes:
    model = build_model(cfg)
    pspec = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    lspec = jax.eval_shape(model.init_lora, jax.random.PRNGKey(0))

    stacked_keys = [k for k in ("layers", "enc_layers", "dec_layers") if k in pspec]
    layer_b = sum(tree_bytes(pspec[k]) for k in stacked_keys)
    n_total = cfg.n_layers + (cfg.n_encoder_layers if cfg.family == "encdec" else 0)
    embed_b = tree_bytes({k: v for k, v in pspec.items()
                          if k in ("embed", "pos_embed", "enc_pos", "proj")})
    head_b = tree_bytes({k: v for k, v in pspec.items()
                         if k in ("head", "cls_head", "final_norm", "enc_norm", "shared")})

    lora_stacked = [k for k in ("layers", "enc_layers") if k in lspec]
    lora_layer_b = sum(tree_bytes(lspec[k]) for k in lora_stacked)
    lora_extra_b = tree_bytes({k: v for k, v in lspec.items()
                               if k not in lora_stacked})
    n_lora_stack = cfg.n_layers if "layers" in lspec else cfg.n_encoder_layers
    return ModelBytes(
        embed=embed_b,
        per_layer=layer_b // max(n_total, 1),
        head=head_b,
        lora_per_layer=lora_layer_b // max(n_lora_stack, 1),
        lora_extra=lora_extra_b,
        n_layers=n_total,
    )


def activation_bytes_training(cfg: ModelConfig, n_layers: int, batch: int,
                              seq_len: int, dtype_bytes: int = 4) -> float:
    """Stored activations for LoRA backprop over n_layers blocks."""
    tok = float(batch) * seq_len
    act = n_layers * tok * cfg.d_model * ACT_FACTOR_BLOCK * dtype_bytes
    if cfg.n_heads:  # attention probabilities (B, H, S, S) per layer
        act += n_layers * float(batch) * cfg.n_heads * seq_len * seq_len * dtype_bytes
    # logits + final norm buffer
    out_dim = cfg.n_classes if cfg.n_classes else cfg.vocab_size
    act += float(batch) * (seq_len if not cfg.n_classes else 1) * out_dim * dtype_bytes
    return act


def optimizer_bytes(lora_bytes: int) -> int:
    return OPTIMIZER_STATES * lora_bytes


@dataclasses.dataclass(frozen=True)
class ServerMemoryReport:
    scheme: str
    params: float
    activations: float
    adapters_and_opt: float

    @property
    def total(self) -> float:
        return self.params + self.activations + self.adapters_and_opt

    @property
    def total_mb(self) -> float:
        return self.total / (1024 ** 2)


def server_memory(cfg: ModelConfig, scheme: str, cuts: Sequence[int],
                  batch: int, seq_len: int, dtype_bytes: int = 4) -> ServerMemoryReport:
    """Server-side memory for the three §V schemes.

    ours : ONE full model resident; clients served sequentially -> one
           in-flight activation set (the deepest server stack among clients)
           + one adapter/optimizer set at a time (per-client sets are tiny
           and stored, but only one is in training state).
    sfl  : U server-side submodels resident AND training in parallel.
    sl   : one submodel at a time (largest), sequential clients.
    """
    mb = model_bytes(cfg)
    n_total = mb.n_layers
    server_layers = [n_total - c for c in cuts]
    u = len(cuts)

    lora_full = mb.lora() + mb.lora_extra

    if scheme == "ours":
        params = mb.params()                       # the single full LLM
        acts = max(activation_bytes_training(cfg, nl, batch, seq_len, dtype_bytes)
                   for nl in server_layers)
        ada = u * lora_full + optimizer_bytes(lora_full)   # U stored, 1 training
    elif scheme == "sfl":
        params = sum(mb.embed * 0 + nl * mb.per_layer + mb.head
                     for nl in server_layers)
        acts = sum(activation_bytes_training(cfg, nl, batch, seq_len, dtype_bytes)
                   for nl in server_layers)
        ada = u * (lora_full + optimizer_bytes(lora_full))
    elif scheme == "sl":
        nl = max(server_layers)
        params = nl * mb.per_layer + mb.head
        acts = activation_bytes_training(cfg, nl, batch, seq_len, dtype_bytes)
        ada = lora_full + optimizer_bytes(lora_full)
    else:
        raise KeyError(scheme)
    return ServerMemoryReport(scheme, float(params), float(acts), float(ada))


def client_memory(cfg: ModelConfig, cut: int, batch: int, seq_len: int,
                  dtype_bytes: int = 4, mb: ModelBytes | None = None) -> float:
    """Client-side bytes: embed + its blocks + adapters + opt + activations.

    ``mb`` takes a precomputed :func:`model_bytes` — callers that probe many
    (cut, batch) candidates (the partition solver, the control plane) pass
    it once instead of re-tracing the model shapes per query."""
    if mb is None:
        mb = model_bytes(cfg)
    params = mb.embed + cut * mb.per_layer
    lora_b = cut * mb.lora_per_layer
    acts = activation_bytes_training(cfg, cut, batch, seq_len, dtype_bytes)
    # remove the head/logits term (client has no head)
    out_dim = cfg.n_classes if cfg.n_classes else cfg.vocab_size
    acts -= float(batch) * (seq_len if not cfg.n_classes else 1) * out_dim * dtype_bytes
    return params + lora_b + optimizer_bytes(lora_b) + acts
