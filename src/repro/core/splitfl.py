"""Algorithm 1 — the memory-efficient SFL training step, as pure JAX.

The three computational pieces of one round:

  client_forward   (Alg.1 l.4, Eq. 3): v_u = f(W_u, R_c^u; x_u)
  server_step      (Alg.1 l.9-11, Eq. 4): resume at the cut on the ONE full
                   model, update R_s^u, emit activation gradients
  client_backward  (Alg.1 l.15): update R_c^u from the activation gradients

Two execution paths, identical semantics (tested against each other):
  * path="sliced": static cut, python loop over owned layers only — what the
    federated simulator runs on CPU;
  * path="scan":   masked lax.scan with a *traced* cut — the production
    form: one compiled executable serves every client (DESIGN.md §4).
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.lora import STACKED_KEYS
from repro.models import layers as L
from repro.optim.adamw import AdamW

PyTree = Any


def client_forward(model, params_c: PyTree, lora_c: PyTree, batch: dict,
                   cut: int, *, path: str = "sliced"):
    """Eq. 3. ``params_c``/``lora_c`` hold only the client's prefix when
    path='sliced' (their stacked leaves have leading dim == cut)."""
    v, _ = model.forward_hidden(params_c, lora_c, batch, cut=cut,
                                side="client", path=path)
    return v


def client_forward_with_vjp(model, params_c: PyTree, lora_c: PyTree,
                            batch: dict, cut: int, *, path: str = "sliced"):
    """Returns (v, vjp_fn) where vjp_fn(dv) -> grads w.r.t. lora_c."""
    def f(lc):
        return client_forward(model, params_c, lc, batch, cut, path=path)

    v, vjp = jax.vjp(f, lora_c)
    return v, lambda dv: vjp(dv)[0]


def server_loss(model, params: PyTree, lora_s: PyTree, v: jax.Array,
                batch: dict, cut, *, path: str = "sliced"):
    """Eq. 4 + loss: resume the full model at the cut with R_s^u."""
    loss, logits = model.loss(params, lora_s, batch, cut=cut, side="server",
                              path=path, x0=v)
    return loss, logits


def make_server_step(model, opt: AdamW, *, path: str = "sliced",
                     static_cut: Optional[int] = None, donate: bool = True):
    """Build the jitted server step.

    signature: (params, lora_s, opt_state, v, batch, cut) ->
               (loss, new_lora_s, new_opt_state, dv)

    With path='scan' the cut is a traced int32 scalar: ONE executable per
    (arch, batch shape) serves every client — LoRA switching is argument
    swapping, never a recompile (the paper's server-side memory story).
    """
    def step(params, lora_s, opt_state, v, batch, cut):
        def loss_fn(lo, vv):
            loss, _ = server_loss(model, params, lo, vv, batch, cut, path=path)
            return loss

        loss, grads = jax.value_and_grad(loss_fn, argnums=(0, 1))(lora_s, v)
        g_lora, g_v = grads
        new_lora, new_opt = opt.update(g_lora, opt_state, lora_s)
        return loss, new_lora, new_opt, g_v

    if static_cut is not None:
        step = functools.partial(step, cut=static_cut)
        return jax.jit(step, donate_argnums=(1, 2) if donate else ())
    return jax.jit(step, donate_argnums=(1, 2) if donate else ())


def make_server_step_cls(model, opt: AdamW, *, path: str = "sliced",
                         static_cut: Optional[int] = None):
    """Server step for classification fine-tuning: the (new, randomly
    initialized) classifier head trains alongside the server-side adapters.

    signature: (params, lora_s, head, opt_state, v, batch[, cut]) ->
               (loss, new_lora_s, new_head, new_opt_state, dv)
    where opt_state is over the pytree {"lora": ..., "head": ...}.
    """
    def step(params, lora_s, head, opt_state, v, batch, cut):
        def loss_fn(trainable, vv):
            pp = dict(params)
            pp["cls_head"] = trainable["head"]
            loss, _ = server_loss(model, pp, trainable["lora"], vv, batch,
                                  cut, path=path)
            return loss

        trainable = {"lora": lora_s, "head": head}
        loss, grads = jax.value_and_grad(loss_fn, argnums=(0, 1))(trainable, v)
        g_tr, g_v = grads
        new_tr, new_opt = opt.update(g_tr, opt_state, trainable)
        return loss, new_tr["lora"], new_tr["head"], new_opt, g_v

    if static_cut is not None:
        step = functools.partial(step, cut=static_cut)
    return jax.jit(step)


def _chunk_slices(u: int, cohort_chunk: Optional[int]):
    k = u if not cohort_chunk or cohort_chunk <= 0 else min(int(cohort_chunk), u)
    return [slice(lo, min(lo + k, u)) for lo in range(0, u, k)]


def _tree_slice(tree: PyTree, sl: slice) -> PyTree:
    return jax.tree.map(lambda a: a[sl], tree)


def _tree_concat(parts) -> PyTree:
    if len(parts) == 1:
        return parts[0]
    return jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *parts)


# ---------------------------------------------------------------------------
# ragged cohort packing (impl="ragged" of the batched server steps)
# ---------------------------------------------------------------------------

def _cohort_to_layer_major(lora_s: PyTree) -> PyTree:
    """Swap cohort-stacked adapter leaves (G, L, ...) to layer-major
    (L, G, ...), so the sliced path's per-layer indexing hands every
    projection a grouped (G, r, K) adapter — the grouped-kernel dispatch
    contract of ``models.layers.lora_apply``.  Server-only keys (e.g.
    hybrid "shared") stay cohort-stacked: their leaves are already
    (G, r, K)."""
    out = {}
    for key, sub in lora_s.items():
        if key in STACKED_KEYS:
            out[key] = jax.tree.map(lambda a: jnp.swapaxes(a, 0, 1), sub)
        else:
            out[key] = sub
    return out


def _flatten_cohort(tree: PyTree) -> PyTree:
    """(G, B, ...) leaves -> (G*B, ...): the ragged concat batch."""
    return jax.tree.map(
        lambda a: a.reshape((a.shape[0] * a.shape[1],) + a.shape[2:]), tree)


def _concrete_cuts(cuts) -> np.ndarray:
    try:
        arr = np.asarray(cuts, dtype=np.int64)
    except Exception:
        raise ValueError(
            "impl='ragged' groups the cohort by CONCRETE cut values (each "
            "distinct cut compiles a static-slice step over only its owned "
            "layers); pass cuts as python ints / numpy — the vmap impl "
            "accepts traced cuts") from None
    if arr.ndim != 1:
        raise ValueError(f"cuts must be a 1-D cohort vector, got {arr.shape}")
    return arr


def _ragged_chunks(cuts: np.ndarray, cohort_chunk: Optional[int]):
    """Group lane indices by cut value (stable), split by cohort_chunk.
    Yields (orig_indices, cut) with indices as python int lists."""
    order = np.argsort(cuts, kind="stable")
    chunks = []
    lo = 0
    while lo < len(order):
        hi = lo
        while hi < len(order) and cuts[order[hi]] == cuts[order[lo]]:
            hi += 1
        grp = order[lo:hi].tolist()
        for sl in _chunk_slices(len(grp), cohort_chunk):
            chunks.append((grp[sl], int(cuts[order[lo]])))
        lo = hi
    return chunks


def _make_server_step_ragged(model, opt: AdamW, *,
                             cohort_chunk: Optional[int] = None,
                             with_head: bool = False):
    """impl="ragged" of the batched server steps: the cohort is grouped by
    cut value and each group runs ONE dispatch over the concatenated
    (G*B, S, d) activation batch — the sliced path executes only layers
    [cut, L) (no masked full-depth scan), and every adapted projection sees
    cohort-grouped (G, r, K) adapters, dispatching to the grouped ragged
    Pallas kernel when ``cfg.lora.impl == 'fused'``.

    Per-client losses are exact: row segments are computationally
    independent, so grad(sum of per-client mean xents) yields each client's
    own gradients; the per-client AdamW update is a vmap.  Known delta vs
    the vmap impl: the sliced path reports no MoE router aux loss (aux=0).
    """
    cfg = model.cfg

    def group_step(params, lora_g, heads_g, opt_g, v_g, batch_g, cut):
        gsz, bsz = v_g.shape[0], v_g.shape[1]
        v_flat = v_g.reshape((gsz * bsz,) + v_g.shape[2:])
        batch_flat = _flatten_cohort(batch_g)

        def loss_fn(trainable, vf):
            lo_lm = _cohort_to_layer_major(
                trainable["lora"] if with_head else trainable)
            if with_head:
                h, _ = model.forward_hidden(params, lo_lm, batch_flat,
                                            cut=cut, side="server",
                                            path="sliced", x0=vf)
                h = L.apply_norm(cfg, params["final_norm"], h)
                pooled = h.reshape(gsz, bsz, *h.shape[1:])[:, :, 0, :]
                logits = jnp.einsum("gbd,gdc->gbc",
                                    pooled.astype(jnp.float32),
                                    trainable["head"])   # per-client heads
                losses = jax.vmap(lambda lg, lb: L.softmax_xent(
                    lg[:, None, :], lb[:, None]))(logits, batch_g["label"])
            else:
                _, logits = model.loss(params, lo_lm, batch_flat, cut=cut,
                                       side="server", path="sliced", x0=vf)
                logits = logits.reshape((gsz, bsz) + logits.shape[1:])
                losses = jax.vmap(L.softmax_xent)(logits, batch_g["targets"])
            return losses.sum(), losses

        # opt_state mirrors the trainable tree: {"lora", "head"} for the
        # classification step, the bare adapter tree for the LM step
        trainable = {"lora": lora_g, "head": heads_g} if with_head else lora_g
        (_, losses), (g_tr, g_v) = jax.value_and_grad(
            loss_fn, argnums=(0, 1), has_aux=True)(trainable, v_flat)
        new_tr, new_opt = jax.vmap(opt.update)(g_tr, opt_g, trainable)
        dv = g_v.reshape(v_g.shape)
        if with_head:
            return losses, new_tr["lora"], new_tr["head"], new_opt, dv
        return losses, new_tr, new_opt, dv

    jitted = jax.jit(group_step, static_argnames=("cut",))

    def take(tree, idx):
        return jax.tree.map(lambda a: jnp.take(a, idx, axis=0), tree)

    def step(params, lora_s, *rest):
        if with_head:
            heads, opt_state, v, batch, cuts = rest
        else:
            opt_state, v, batch, cuts = rest
            heads = None
        cuts_np = _concrete_cuts(cuts)
        outs, perm = [], []
        for idx_list, cut in _ragged_chunks(cuts_np, cohort_chunk):
            idx = jnp.asarray(idx_list, jnp.int32)
            outs.append(jitted(
                params, take(lora_s, idx),
                jnp.take(heads, idx, axis=0) if with_head else None,
                take(opt_state, idx), jnp.take(v, idx, axis=0),
                take(batch, idx), cut=cut))
            perm.extend(idx_list)
        inv = jnp.asarray(np.argsort(np.asarray(perm)), jnp.int32)
        return take(_tree_concat(outs), inv)   # back to cohort order

    return step


def make_server_step_batched(model, opt: AdamW, *,
                             cohort_chunk: Optional[int] = None,
                             donate: bool = True, impl: str = "vmap"):
    """Cohort-batched server step: ONE vmapped executable advances a whole
    chunk of clients instead of U sequential dispatches.

    signature: (params, lora_s, opt_state, v, batch, cuts) ->
               (losses, new_lora_s, new_opt_state, dv)

    Every argument after ``params`` carries a leading cohort axis U: the
    per-client full-shape server adapters (``lora.embed_in_full_shape`` +
    ``lora.stack_trees``), optimizer states, activations, batches, and an
    int32 ``cuts`` vector.  The cut is *traced* per cohort lane (path='scan'),
    so heterogeneous cuts share the compiled executable.  ``cohort_chunk``
    bounds how many clients are materialized per dispatch — the paper's
    sequential server is exactly ``cohort_chunk=1``.

    ``impl`` selects the execution path (EngineConfig.cohort_impl):
      * "vmap" (default): the masked-scan lane-per-client form above — every
        lane computes all L layers and masks the client prefix;
      * "ragged": cut-grouped concat batches through
        :func:`_make_server_step_ragged` — each group computes only its own
        [cut, L) suffix (the padded-FLOPs win grows with cut spread) and
        feeds cohort-grouped adapters to the grouped Pallas kernel path.
    """
    if impl == "ragged":
        return _make_server_step_ragged(model, opt,
                                        cohort_chunk=cohort_chunk,
                                        with_head=False)
    if impl != "vmap":
        raise KeyError(f"unknown batched-server impl {impl!r}; "
                       f"choose 'vmap' or 'ragged'")
    def one(params, lora_s, opt_state, v, batch, cut):
        def loss_fn(lo, vv):
            loss, _ = server_loss(model, params, lo, vv, batch, cut,
                                  path="scan")
            return loss

        loss, grads = jax.value_and_grad(loss_fn, argnums=(0, 1))(lora_s, v)
        g_lora, g_v = grads
        new_lora, new_opt = opt.update(g_lora, opt_state, lora_s)
        return loss, new_lora, new_opt, g_v

    vstep = jax.vmap(one, in_axes=(None, 0, 0, 0, 0, 0))
    jitted = jax.jit(vstep, donate_argnums=(1, 2) if donate else ())

    def step(params, lora_s, opt_state, v, batch, cuts):
        cuts = jnp.asarray(cuts, jnp.int32)
        outs = [jitted(params, _tree_slice(lora_s, sl), _tree_slice(opt_state, sl),
                       v[sl], _tree_slice(batch, sl), cuts[sl])
                for sl in _chunk_slices(int(cuts.shape[0]), cohort_chunk)]
        return _tree_concat(outs)

    return step


def make_server_step_cls_batched(model, opt: AdamW, *,
                                 cohort_chunk: Optional[int] = None,
                                 donate: bool = False, impl: str = "vmap"):
    """Cohort-batched classification server step (per-client heads train
    alongside the server adapters).

    signature: (params, lora_s, heads, opt_state, v, batch, cuts) ->
               (losses, new_lora_s, new_heads, new_opt_state, dv)
    with the same leading cohort axis conventions as
    :func:`make_server_step_batched`; ``opt_state`` is over the stacked
    pytree {"lora": ..., "head": ...}.  ``impl`` as in
    :func:`make_server_step_batched`.
    """
    if impl == "ragged":
        return _make_server_step_ragged(model, opt,
                                        cohort_chunk=cohort_chunk,
                                        with_head=True)
    if impl != "vmap":
        raise KeyError(f"unknown batched-server impl {impl!r}; "
                       f"choose 'vmap' or 'ragged'")
    def one(params, lora_s, head, opt_state, v, batch, cut):
        def loss_fn(trainable, vv):
            pp = dict(params)
            pp["cls_head"] = trainable["head"]
            loss, _ = server_loss(model, pp, trainable["lora"], vv, batch,
                                  cut, path="scan")
            return loss

        trainable = {"lora": lora_s, "head": head}
        loss, grads = jax.value_and_grad(loss_fn, argnums=(0, 1))(trainable, v)
        g_tr, g_v = grads
        new_tr, new_opt = opt.update(g_tr, opt_state, trainable)
        return loss, new_tr["lora"], new_tr["head"], new_opt, g_v

    vstep = jax.vmap(one, in_axes=(None, 0, 0, 0, 0, 0, 0))
    jitted = jax.jit(vstep, donate_argnums=(1, 2, 3) if donate else ())

    def step(params, lora_s, heads, opt_state, v, batch, cuts):
        cuts = jnp.asarray(cuts, jnp.int32)
        outs = [jitted(params, _tree_slice(lora_s, sl), heads[sl],
                       _tree_slice(opt_state, sl), v[sl],
                       _tree_slice(batch, sl), cuts[sl])
                for sl in _chunk_slices(int(cuts.shape[0]), cohort_chunk)]
        return _tree_concat(outs)

    return step


def make_client_step(model, opt: AdamW, cut: int, *, path: str = "sliced"):
    """Build the jitted client fwd+bwd pair for a fixed (static) cut.

    forward:  (params_c, lora_c, batch)              -> v
    backward: (params_c, lora_c, opt_state, batch, dv) -> (new_lora_c, new_opt)
    """
    @jax.jit
    def fwd(params_c, lora_c, batch):
        return client_forward(model, params_c, lora_c, batch, cut, path=path)

    @jax.jit
    def bwd(params_c, lora_c, opt_state, batch, dv):
        _, vjp_fn = client_forward_with_vjp(model, params_c, lora_c, batch,
                                            cut, path=path)
        g = vjp_fn(dv)
        new_lora, new_opt = opt.update(g, opt_state, lora_c)
        return new_lora, new_opt

    return fwd, bwd


def make_full_train_step(model, opt: AdamW, *, remat: bool = False,
                         path: str = "scan", donate: bool = True):
    """Centralized LoRA fine-tuning step (cut=0 oracle + production step).

    signature: (params, lora, opt_state, batch) -> (loss, lora, opt_state)
    """
    def step(params, lora, opt_state, batch):
        def loss_fn(lo):
            loss, _ = model.loss(params, lo, batch, cut=0, side="full",
                                 path=path, remat=remat)
            return loss

        loss, g = jax.value_and_grad(loss_fn)(lora)
        new_lora, new_opt = opt.update(g, opt_state, lora)
        return loss, new_lora, new_opt

    return jax.jit(step, donate_argnums=(1, 2) if donate else ())


class CohortAdapterStore:
    """Cohort-indexed per-client adapter + optimizer state for population-
    scale federation: only the SAMPLED clients ever hold materialized
    trees.

    The per-object ``Simulator`` eagerly builds every client's
    ``(client_lora, client_opt, server_lora, head, server_opt)`` tuple at
    init and re-builds ALL of them from the aggregated global at each sync
    commit.  At 10^4 clients that is the memory wall this store removes:
    it keeps ONE standing global ``(full adapter, head)`` plus a dict of
    slots for the clients a cohort actually touched, and materializes a
    slot on first use from a per-cut TEMPLATE cache —

        client_lora = split_lora(global_full, cut)[0]
        server_lora = embed_in_full_shape(split[1], spec, cut, "server")
        opt states  = opt.init(...) on those trees

    ``split_lora``/``embed_in_full_shape`` are pure slice/scatter ops and
    ``opt.init`` is deterministic, so a materialized slot is bit-identical
    to the eager Simulator's standing state for an untouched client — the
    cross-engine parity grid in tests/test_population_training.py leans on
    exactly this equivalence.  Distinct cuts share one template; slots are
    shallow copies, so untouched trees alias until a training step
    replaces them.

    Two global-update modes mirror the two commit families:
      * ``reset_global``  (sync barrier): every client re-enters from the
        new global -> drop ALL slots and caches;
      * ``set_global``    (async): non-contributors keep training on their
        in-flight state -> keep slots, invalidate only the fresh-view
        caches; callers re-materialize the contributors via ``drop``.
    """

    def __init__(self, lora_spec, opt: AdamW, global_full, global_head,
                 cut_of):
        self.lora_spec = lora_spec
        self.opt = opt
        self.global_full = global_full
        self.global_head = global_head
        self._cut_of = cut_of            # uid -> cut
        self._slots: dict = {}           # uid -> slot dict
        self._templates: dict = {}       # cut -> template slot
        self._views: dict = {}           # cut -> (client_view, server_split)
        self._slot_nbytes: dict = {}     # cut -> bytes one slot holds

    # ----------------------------------------------------------- materialize
    def _template(self, cut: int) -> dict:
        tpl = self._templates.get(cut)
        if tpl is None:
            from repro.core import lora as lora_lib
            c, s = lora_lib.split_lora(self.global_full, cut)
            full_shape = lora_lib.embed_in_full_shape(
                s, self.lora_spec, cut, "server")
            tpl = {
                "client_lora": c,
                "client_opt": self.opt.init(c),
                "server_lora": full_shape,
                "head": self.global_head,
                "server_opt": self.opt.init({"lora": full_shape,
                                             "head": self.global_head}),
            }
            self._templates[cut] = tpl
        return tpl

    def materialize(self, u: int) -> dict:
        """The slot for client ``u``, built from the standing global on
        first touch (shallow copy of the cut's template)."""
        u = int(u)
        slot = self._slots.get(u)
        if slot is None:
            slot = dict(self._template(int(self._cut_of(u))))
            self._slots[u] = slot
        return slot

    def slot(self, u: int) -> dict:
        return self._slots[int(u)]

    def peek(self, u: int):
        """The slot if materialized, else None (no side effects)."""
        return self._slots.get(int(u))

    def touched(self):
        """Materialized uids, ascending."""
        return sorted(self._slots)

    def fresh_views(self, cut: int):
        """``(client_view, server_split_view)`` of the standing global at
        ``cut`` — what an untouched client's state looks like, shared
        across every absent client at that cut (cached slices, no
        per-client copies)."""
        pr = self._views.get(cut)
        if pr is None:
            from repro.core import lora as lora_lib
            pr = lora_lib.split_lora(self.global_full, cut)
            self._views[cut] = pr
        return pr

    # ---------------------------------------------------------- global swaps
    def drop(self, u: int) -> None:
        self._slots.pop(int(u), None)

    def set_global(self, full, head) -> None:
        """Async commit: new standing global; in-flight slots survive."""
        self.global_full = full
        self.global_head = head
        self._templates.clear()
        self._views.clear()

    def reset_global(self, full, head) -> None:
        """Sync barrier commit: new global, every slot re-enters fresh."""
        self.set_global(full, head)
        self._slots.clear()

    # ------------------------------------------------------------ accounting
    def slot_nbytes(self, cut: int) -> float:
        """Bytes one materialized slot at ``cut`` holds (adapters + heads +
        optimizer state), measured on the actual template leaves."""
        nb = self._slot_nbytes.get(cut)
        if nb is None:
            tpl = self._template(cut)
            nb = float(sum(leaf.nbytes for leaf in jax.tree.leaves(tpl)))
            self._slot_nbytes[cut] = nb
        return nb

    def resident_nbytes(self) -> float:
        """Bytes all currently materialized slots hold — the cohort-resident
        figure the obs ledger prices per round."""
        return float(sum(self.slot_nbytes(int(self._cut_of(u)))
                         for u in self._slots))
