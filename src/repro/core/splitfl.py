"""Algorithm 1 — the memory-efficient SFL training step, as pure JAX.

The three computational pieces of one round:

  client_forward   (Alg.1 l.4, Eq. 3): v_u = f(W_u, R_c^u; x_u)
  server_step      (Alg.1 l.9-11, Eq. 4): resume at the cut on the ONE full
                   model, update R_s^u, emit activation gradients
  client_backward  (Alg.1 l.15): update R_c^u from the activation gradients

Two execution paths, identical semantics (tested against each other):
  * path="sliced": static cut, python loop over owned layers only — what the
    federated simulator runs on CPU;
  * path="scan":   masked lax.scan with a *traced* cut — the production
    form: one compiled executable serves every client (DESIGN.md §4).
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.optim.adamw import AdamW

PyTree = Any


def client_forward(model, params_c: PyTree, lora_c: PyTree, batch: dict,
                   cut: int, *, path: str = "sliced"):
    """Eq. 3. ``params_c``/``lora_c`` hold only the client's prefix when
    path='sliced' (their stacked leaves have leading dim == cut)."""
    v, _ = model.forward_hidden(params_c, lora_c, batch, cut=cut,
                                side="client", path=path)
    return v


def client_forward_with_vjp(model, params_c: PyTree, lora_c: PyTree,
                            batch: dict, cut: int, *, path: str = "sliced"):
    """Returns (v, vjp_fn) where vjp_fn(dv) -> grads w.r.t. lora_c."""
    def f(lc):
        return client_forward(model, params_c, lc, batch, cut, path=path)

    v, vjp = jax.vjp(f, lora_c)
    return v, lambda dv: vjp(dv)[0]


def server_loss(model, params: PyTree, lora_s: PyTree, v: jax.Array,
                batch: dict, cut, *, path: str = "sliced"):
    """Eq. 4 + loss: resume the full model at the cut with R_s^u."""
    loss, logits = model.loss(params, lora_s, batch, cut=cut, side="server",
                              path=path, x0=v)
    return loss, logits


def make_server_step(model, opt: AdamW, *, path: str = "sliced",
                     static_cut: Optional[int] = None, donate: bool = True):
    """Build the jitted server step.

    signature: (params, lora_s, opt_state, v, batch, cut) ->
               (loss, new_lora_s, new_opt_state, dv)

    With path='scan' the cut is a traced int32 scalar: ONE executable per
    (arch, batch shape) serves every client — LoRA switching is argument
    swapping, never a recompile (the paper's server-side memory story).
    """
    def step(params, lora_s, opt_state, v, batch, cut):
        def loss_fn(lo, vv):
            loss, _ = server_loss(model, params, lo, vv, batch, cut, path=path)
            return loss

        loss, grads = jax.value_and_grad(loss_fn, argnums=(0, 1))(lora_s, v)
        g_lora, g_v = grads
        new_lora, new_opt = opt.update(g_lora, opt_state, lora_s)
        return loss, new_lora, new_opt, g_v

    if static_cut is not None:
        step = functools.partial(step, cut=static_cut)
        return jax.jit(step, donate_argnums=(1, 2) if donate else ())
    return jax.jit(step, donate_argnums=(1, 2) if donate else ())


def make_server_step_cls(model, opt: AdamW, *, path: str = "sliced",
                         static_cut: Optional[int] = None):
    """Server step for classification fine-tuning: the (new, randomly
    initialized) classifier head trains alongside the server-side adapters.

    signature: (params, lora_s, head, opt_state, v, batch[, cut]) ->
               (loss, new_lora_s, new_head, new_opt_state, dv)
    where opt_state is over the pytree {"lora": ..., "head": ...}.
    """
    def step(params, lora_s, head, opt_state, v, batch, cut):
        def loss_fn(trainable, vv):
            pp = dict(params)
            pp["cls_head"] = trainable["head"]
            loss, _ = server_loss(model, pp, trainable["lora"], vv, batch,
                                  cut, path=path)
            return loss

        trainable = {"lora": lora_s, "head": head}
        loss, grads = jax.value_and_grad(loss_fn, argnums=(0, 1))(trainable, v)
        g_tr, g_v = grads
        new_tr, new_opt = opt.update(g_tr, opt_state, trainable)
        return loss, new_tr["lora"], new_tr["head"], new_opt, g_v

    if static_cut is not None:
        step = functools.partial(step, cut=static_cut)
    return jax.jit(step)


def _chunk_slices(u: int, cohort_chunk: Optional[int]):
    k = u if not cohort_chunk or cohort_chunk <= 0 else min(int(cohort_chunk), u)
    return [slice(lo, min(lo + k, u)) for lo in range(0, u, k)]


def _tree_slice(tree: PyTree, sl: slice) -> PyTree:
    return jax.tree.map(lambda a: a[sl], tree)


def _tree_concat(parts) -> PyTree:
    if len(parts) == 1:
        return parts[0]
    return jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *parts)


def make_server_step_batched(model, opt: AdamW, *,
                             cohort_chunk: Optional[int] = None,
                             donate: bool = True):
    """Cohort-batched server step: ONE vmapped executable advances a whole
    chunk of clients instead of U sequential dispatches.

    signature: (params, lora_s, opt_state, v, batch, cuts) ->
               (losses, new_lora_s, new_opt_state, dv)

    Every argument after ``params`` carries a leading cohort axis U: the
    per-client full-shape server adapters (``lora.embed_in_full_shape`` +
    ``lora.stack_trees``), optimizer states, activations, batches, and an
    int32 ``cuts`` vector.  The cut is *traced* per cohort lane (path='scan'),
    so heterogeneous cuts share the compiled executable.  ``cohort_chunk``
    bounds how many clients are materialized per dispatch — the paper's
    sequential server is exactly ``cohort_chunk=1``.
    """
    def one(params, lora_s, opt_state, v, batch, cut):
        def loss_fn(lo, vv):
            loss, _ = server_loss(model, params, lo, vv, batch, cut,
                                  path="scan")
            return loss

        loss, grads = jax.value_and_grad(loss_fn, argnums=(0, 1))(lora_s, v)
        g_lora, g_v = grads
        new_lora, new_opt = opt.update(g_lora, opt_state, lora_s)
        return loss, new_lora, new_opt, g_v

    vstep = jax.vmap(one, in_axes=(None, 0, 0, 0, 0, 0))
    jitted = jax.jit(vstep, donate_argnums=(1, 2) if donate else ())

    def step(params, lora_s, opt_state, v, batch, cuts):
        cuts = jnp.asarray(cuts, jnp.int32)
        outs = [jitted(params, _tree_slice(lora_s, sl), _tree_slice(opt_state, sl),
                       v[sl], _tree_slice(batch, sl), cuts[sl])
                for sl in _chunk_slices(int(cuts.shape[0]), cohort_chunk)]
        return _tree_concat(outs)

    return step


def make_server_step_cls_batched(model, opt: AdamW, *,
                                 cohort_chunk: Optional[int] = None,
                                 donate: bool = False):
    """Cohort-batched classification server step (per-client heads train
    alongside the server adapters).

    signature: (params, lora_s, heads, opt_state, v, batch, cuts) ->
               (losses, new_lora_s, new_heads, new_opt_state, dv)
    with the same leading cohort axis conventions as
    :func:`make_server_step_batched`; ``opt_state`` is over the stacked
    pytree {"lora": ..., "head": ...}.
    """
    def one(params, lora_s, head, opt_state, v, batch, cut):
        def loss_fn(trainable, vv):
            pp = dict(params)
            pp["cls_head"] = trainable["head"]
            loss, _ = server_loss(model, pp, trainable["lora"], vv, batch,
                                  cut, path="scan")
            return loss

        trainable = {"lora": lora_s, "head": head}
        loss, grads = jax.value_and_grad(loss_fn, argnums=(0, 1))(trainable, v)
        g_tr, g_v = grads
        new_tr, new_opt = opt.update(g_tr, opt_state, trainable)
        return loss, new_tr["lora"], new_tr["head"], new_opt, g_v

    vstep = jax.vmap(one, in_axes=(None, 0, 0, 0, 0, 0, 0))
    jitted = jax.jit(vstep, donate_argnums=(1, 2, 3) if donate else ())

    def step(params, lora_s, heads, opt_state, v, batch, cuts):
        cuts = jnp.asarray(cuts, jnp.int32)
        outs = [jitted(params, _tree_slice(lora_s, sl), heads[sl],
                       _tree_slice(opt_state, sl), v[sl],
                       _tree_slice(batch, sl), cuts[sl])
                for sl in _chunk_slices(int(cuts.shape[0]), cohort_chunk)]
        return _tree_concat(outs)

    return step


def make_client_step(model, opt: AdamW, cut: int, *, path: str = "sliced"):
    """Build the jitted client fwd+bwd pair for a fixed (static) cut.

    forward:  (params_c, lora_c, batch)              -> v
    backward: (params_c, lora_c, opt_state, batch, dv) -> (new_lora_c, new_opt)
    """
    @jax.jit
    def fwd(params_c, lora_c, batch):
        return client_forward(model, params_c, lora_c, batch, cut, path=path)

    @jax.jit
    def bwd(params_c, lora_c, opt_state, batch, dv):
        _, vjp_fn = client_forward_with_vjp(model, params_c, lora_c, batch,
                                            cut, path=path)
        g = vjp_fn(dv)
        new_lora, new_opt = opt.update(g, opt_state, lora_c)
        return new_lora, new_opt

    return fwd, bwd


def make_full_train_step(model, opt: AdamW, *, remat: bool = False,
                         path: str = "scan", donate: bool = True):
    """Centralized LoRA fine-tuning step (cut=0 oracle + production step).

    signature: (params, lora, opt_state, batch) -> (loss, lora, opt_state)
    """
    def step(params, lora, opt_state, batch):
        def loss_fn(lo):
            loss, _ = model.loss(params, lo, batch, cut=0, side="full",
                                 path=path, remat=remat)
            return loss

        loss, g = jax.value_and_grad(loss_fn)(lora)
        new_lora, new_opt = opt.update(g, opt_state, lora)
        return loss, new_lora, new_opt

    return jax.jit(step, donate_argnums=(1, 2) if donate else ())
